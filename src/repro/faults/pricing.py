"""Checkpoint/restore costs priced by the hardware model, plus Young/Daly.

The dormant seed checkpoint code (:mod:`repro.checkpoint.store`,
:mod:`repro.core.sim_checkpoint`) measures real wall-clock writes; the
fleet simulator needs the same flow as *simulated, priced events*.  A
:class:`CheckpointModel` prices one checkpoint cycle from the chip spec:

* **save** — the training state (the store's ``arrays.npz`` payload,
  :func:`tree_nbytes` of the state tree, or the allocator's
  ``peak_hbm_bytes`` when only a SimReport is available) is read out of
  HBM at ``hbm_bw`` and shipped off-chip at ``dcn_bw`` — the same
  "snapshot global memory" step the paper's §III-F fidelity switch takes
  before resuming in detailed mode;
* **restore** — the reverse path (host -> HBM), plus, for a multi-device
  gang, one re-shard sweep over the ICI: each member holds ``1/g`` of the
  state after its host pull and all-gathers the rest, the textbook
  ``(g-1)/g * S`` bytes per link direction — so restoring onto a
  different (or smaller) sub-slice genuinely pays fabric traffic.

``write_s``/``restore_s`` override the computed costs with fixed values
for hand-computable scenario tests and Young/Daly sweeps.

:func:`daly_interval` is the analytic optimum the checkpoint-interval
sweep benchmark validates against: for checkpoint cost ``w`` and MTBF
``M``, overhead/step-loss is minimized near ``sqrt(2 * w * M)`` (Young
1974; Daly 2006 first-order form).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

from repro.core.hw import HardwareSpec


def tree_nbytes(tree: Any) -> int:
    """Checkpoint payload bytes of a state pytree — the same leaf math
    :func:`repro.checkpoint.store.save` ships to ``arrays.npz`` (flatten,
    gather to host, sum of per-leaf nbytes)."""
    import jax
    import numpy as np

    leaves, _ = jax.tree_util.tree_flatten(tree)
    return int(sum(np.asarray(jax.device_get(l)).nbytes for l in leaves))


@dataclass(frozen=True)
class CheckpointModel:
    """Cadence + priced costs of the checkpoint-restore cycle.

    ``interval_s`` is the target cadence on the *simulated* clock; the
    cluster loop converts it to a whole number of training steps per job
    (at least one step between checkpoints).  ``interval_s <= 0`` keeps
    the pricing (restores still cost time) but disables cadenced saves.
    """

    interval_s: float = 0.0
    write_s: Optional[float] = None     # fixed save cost override
    restore_s: Optional[float] = None   # fixed restore cost override
    base_s: float = 0.0                 # per-cycle quiesce/barrier cost

    def save_seconds(self, state_bytes: float, hw: HardwareSpec) -> float:
        """One cadenced checkpoint write on the simulated clock."""
        if self.write_s is not None:
            return self.write_s
        return (self.base_s + state_bytes / hw.hbm_bw
                + state_bytes / hw.dcn_bw)

    def restore_seconds(self, state_bytes: float, hw: HardwareSpec,
                        gang: int = 1) -> float:
        """Restore (+ re-shard for a gang) before a failed job resumes."""
        if self.restore_s is not None:
            return self.restore_s
        g = max(gang, 1)
        seconds = (self.base_s + state_bytes / g / hw.dcn_bw
                   + state_bytes / hw.hbm_bw)
        if g > 1:
            ici_bw = hw.ici_links_per_axis * hw.ici_link_bw
            seconds += (g - 1) / g * state_bytes / ici_bw \
                + (g - 1) * hw.ici_latency_s
        return seconds

    def steps_per_checkpoint(self, per_step_s: float) -> int:
        """Cadence in whole training steps (0 = checkpointing disabled)."""
        if self.interval_s <= 0 or per_step_s <= 0:
            return 0
        return max(int(round(self.interval_s / per_step_s)), 1)

    def cache_key(self) -> tuple:
        """Hashable identity for simulation-cache keys: two engine prices
        computed under different checkpoint specs must never alias."""
        return ("ckpt", self.interval_s, self.write_s, self.restore_s,
                self.base_s)


def parse_checkpoint_spec(spec: str) -> CheckpointModel:
    """CLI grammar for ``--checkpoint``::

        every:10m                       # cadence only, hardware-priced costs
        every:600,write:2,restore:5     # fixed-cost overrides (seconds)
        every:1h,base:0.5               # + per-cycle quiesce cost
    """
    from repro.faults.processes import parse_seconds

    kw = {}
    fields = {"every": "interval_s", "write": "write_s",
              "restore": "restore_s", "base": "base_s"}
    for part in str(spec).split(","):
        part = part.strip()
        if not part:
            continue
        key, _, value = part.partition(":")
        if not value and key not in fields:
            # bare duration shorthand: "--checkpoint 600" = every:600
            kw["interval_s"] = parse_seconds(key)
            continue
        if key not in fields:
            raise KeyError(f"unknown checkpoint spec field {key!r} "
                           f"(expected {' | '.join(sorted(fields))})")
        kw[fields[key]] = parse_seconds(value)
    if not kw:
        raise KeyError(f"empty checkpoint spec {spec!r}")
    return CheckpointModel(**kw)


def daly_interval(write_s: float, mtbf_s: float) -> float:
    """Young/Daly first-order optimal checkpoint interval
    ``sqrt(2 * w * MTBF)`` (work between checkpoints, excluding the
    checkpoint itself)."""
    if write_s <= 0 or not math.isfinite(mtbf_s):
        return math.inf
    return math.sqrt(2.0 * write_s * mtbf_s)
