"""Gang slowdown on a degraded fabric: how much a broken link costs.

When an ICI link fails under a running (or about-to-start) gang, the
collectives the gang issues every step no longer see the healthy fabric:
traffic that flowed down the dead link re-routes over surviving neighbors
(BFS detours in :meth:`repro.topology.graph.Topology.route`) and
*serializes* with the traffic already camped there.  The cluster loop
folds that into scheduling with one scalar: the **gang dilation** — the
ratio of the gang's all-reduce schedule time on the degraded fabric to
the same schedule on the healthy fabric.  Per-step gang time is then
``healthy_per_step * dilation``, i.e. the per-step collective share is
conservatively assumed to dominate the stretch.

The probe payload is a fixed 64 MiB all-reduce — big enough that the
schedule is bandwidth-dominated (latency hops cancel in the ratio for
same-phase-count reroutes), which is the regime where a lost link
actually hurts.

If the removals *partition* the gang (no surviving route between two
members), the lowering raises ``ValueError``; the dilation then falls
back to ``len(members)`` — fully serialized, the pessimistic bound — so
the simulation keeps running rather than wedging.  Schedulers should
avoid placing gangs across broken links in the first place (the Locality
policy does), making this the last-resort path.
"""
from __future__ import annotations

from functools import lru_cache
from typing import AbstractSet, Optional, Sequence, Tuple

from repro.core.hw import HardwareSpec
from repro.topology.graph import Topology
from repro.topology.lowering import lower_collective

#: bandwidth-dominated probe payload for the dilation ratio (64 MiB)
PROBE_BYTES = 64 * 1024 * 1024


def gang_dilation(topo: Topology, members: Sequence[int],
                  broken: Optional[AbstractSet[Tuple[int, int]]],
                  hw: HardwareSpec) -> float:
    """Degraded/healthy all-reduce time ratio for a gang (>= 1.0).

    ``members`` are global device ids on ``topo``; ``broken`` holds
    undirected id pairs of failed physical links.  Returns 1.0 when no
    broken link can affect the gang, ``len(members)`` when the gang is
    partitioned by the removals.  Pure in its arguments, so the probe
    ratio is memoized — the cluster loop re-asks for the same (gang,
    outage) pair on every epoch/checkpoint event.
    """
    if not broken or len(members) <= 1:
        return 1.0
    return _dilation_cached(topo, tuple(members), frozenset(broken), hw)


@lru_cache(maxsize=4096)
def _dilation_cached(topo: Topology, members: Tuple[int, ...],
                     broken: frozenset, hw: HardwareSpec) -> float:
    # behind the lru_cache: the span/counter record probe computations
    # actually performed, not memoized re-asks
    from repro.obs.metrics import REGISTRY
    from repro.obs.trace import TRACER
    REGISTRY.counter("faults_dilation_probes_total").inc()
    with TRACER.span("faults.gang_dilation", gang=len(members),
                     broken_links=len(broken)):
        healthy = lower_collective("all-reduce", PROBE_BYTES, members, topo,
                                   hw)
        if healthy.seconds <= 0:
            return 1.0
        try:
            degraded = lower_collective("all-reduce", PROBE_BYTES, members,
                                        topo, hw, broken=broken)
        except ValueError:
            REGISTRY.counter("faults_gang_partitions_total").inc()
            return float(len(members))
        return max(degraded.seconds / healthy.seconds, 1.0)
