"""repro.faults — failure processes, checkpoint pricing, and degraded-fabric
rerouting for the fleet simulator.

The paper's simulator prices *healthy* execution; real fleets spend a
measurable fraction of their hours failing, restoring, and running
degraded.  This package supplies the three ingredients the cluster event
loop (:mod:`repro.cluster.events`) composes into that story:

* :mod:`repro.faults.processes` — *who breaks, when*: seeded renewal
  failure processes (exponential / heavy-tailed Weibull MTBF, exponential
  MTTR) and explicit planned-outage lists, per device and per undirected
  ICI link, plus the CLI's ``--failures mtbf:...`` grammar;
* :mod:`repro.faults.pricing` — *what recovery costs*: checkpoint save /
  restore cycles priced from the chip spec (HBM + DCN + gang re-shard
  over ICI), cadence conversion, and the Young/Daly optimal interval the
  sweep benchmark validates against;
* :mod:`repro.faults.reroute` — *how survivors slow down*: the gang
  dilation factor from lowering the gang's all-reduce over the surviving
  fabric only.

Event flow on a failure: **fail** (outage event fires, gang killed, work
since the last checkpoint is lost) -> **detect** (job requeued; an elastic
gang reshapes onto the largest surviving sub-slice) -> **restore** (priced
checkpoint read + re-shard) -> **resume** (remaining steps, possibly
dilated by broken links).  See ``docs/ARCHITECTURE.md``.
"""
from repro.faults.pricing import (CheckpointModel, daly_interval,
                                  parse_checkpoint_spec, tree_nbytes)
from repro.faults.processes import (DEVICE, LINK, FailureProcess, Outage,
                                    PlannedFailures, StochasticFailures,
                                    link_key, parse_failure_spec,
                                    parse_seconds)
from repro.faults.reroute import PROBE_BYTES, gang_dilation

__all__ = [
    "DEVICE",
    "LINK",
    "Outage",
    "FailureProcess",
    "PlannedFailures",
    "StochasticFailures",
    "link_key",
    "parse_failure_spec",
    "parse_seconds",
    "CheckpointModel",
    "parse_checkpoint_spec",
    "daly_interval",
    "tree_nbytes",
    "PROBE_BYTES",
    "gang_dilation",
]
