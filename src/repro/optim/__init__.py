from repro.optim.adamw import (
    TrainState, abstract_state, adamw_update, global_norm, init_state, state_axes,
)
from repro.optim.schedule import warmup_cosine

__all__ = ["TrainState", "abstract_state", "adamw_update", "global_norm",
           "init_state", "state_axes", "warmup_cosine"]
