"""AdamW with fp32 master weights (mixed-precision training).

Optimizer state inherits each parameter's logical sharding axes, so under FSDP
rules the master/m/v tensors are sharded over (data x model) exactly like the
bf16 parameters — the ZeRO-style memory layout that lets dbrx-132b fit
16 GB/chip on the 256-chip pod.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class TrainState(NamedTuple):
    step: jax.Array            # () int32
    params: Any                # compute-dtype (bf16) pytree
    master: Any                # fp32 master copy
    m: Any                     # fp32 first moment
    v: Any                     # fp32 second moment


def init_state(params: Any) -> TrainState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      master, jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def abstract_state(abstract_params: Any) -> TrainState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(jax.ShapeDtypeStruct((), jnp.int32), abstract_params,
                      jax.tree.map(f32, abstract_params),
                      jax.tree.map(f32, abstract_params),
                      jax.tree.map(f32, abstract_params))


def state_axes(param_axes: Any) -> TrainState:
    """Logical axes pytree matching TrainState structure."""
    return TrainState((), param_axes, param_axes, param_axes, param_axes)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(state: TrainState, grads: Any, cfg: TrainConfig,
                 lr_fn: Callable) -> Tuple[TrainState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = lr_fn(step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = treedef.flatten_up_to(state.master)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, ma, m, v) for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v)]
    new_master = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, state.params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(step, new_params, new_master, new_m, new_v), metrics
