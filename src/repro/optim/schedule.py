"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def warmup_cosine(cfg: TrainConfig):
    """Linear warmup -> cosine decay to 10% of peak."""
    peak, warm, total = cfg.learning_rate, cfg.warmup_steps, cfg.total_steps

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak * step / jnp.maximum(warm, 1)
        frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
        cos_lr = 0.1 * peak + 0.9 * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warm, warm_lr, cos_lr)

    return lr
