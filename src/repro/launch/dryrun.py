import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without TPUs.

For every (architecture x input-shape) cell this lowers + compiles the step
function for the production meshes:

    single-pod:  (16, 16)      axes (data, model)        = 256 chips
    multi-pod:   (2, 16, 16)   axes (pod, data, model)   = 512 chips

and records ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
(FLOPs/bytes) and the collective-op byte census parsed from the compiled HLO
(for the roofline's collective term).  Artifacts land in
``experiments/dryrun/<arch>.<shape>.<mesh>.json`` and feed EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-cell ...]
"""
import argparse
import gc
import json
import sys
import time
import traceback

import jax

from repro import config as C
from repro.launch.mesh import make_production_mesh, production_mesh_config
from repro.runtime.steps import bundle_for

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def input_specs(run_cfg):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    bundle = bundle_for(run_cfg)
    return bundle.abstract_inputs


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: bool = False, quiet: bool = False) -> dict:
    entry = C.get(arch)
    shape = C.SHAPES_BY_NAME[shape_name]
    reason = entry.skip_reason(shape)
    if reason is not None:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    import dataclasses
    mesh_cfg = production_mesh_config(multi_pod=multi_pod)
    train_cfg = dataclasses.replace(C.TrainConfig(), accum_steps=entry.accum_steps)
    run_cfg = C.RunConfig(model=entry.full, shape=shape, mesh=mesh_cfg,
                          train=train_cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    bundle = bundle_for(run_cfg, mesh)
    with mesh:
        lowered = bundle.lower(mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from repro.core.capture import unwrap_cost_analysis
    cost = unwrap_cost_analysis(compiled.cost_analysis())
    n_dev = mesh_cfg.num_devices

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh_cfg.shape)),
        "num_devices": n_dev,
        "kind": shape.kind,
        "accum_steps": entry.accum_steps,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "cost": {k: cost.get(k) for k in ("flops", "transcendentals",
                                          "bytes accessed")},
    }
    # per-device live-bytes upper bound: args + temps (aliased args re-used)
    result["memory"]["per_device_bytes"] = (
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes)

    # collective census + trip-count-aware op walk (the simulator IR parser)
    try:
        from repro.core.engine import Engine
        from repro.core.hlo_ir import parse_hlo_module, summarize_collectives
        hlo_text = compiled.as_text()
        module = parse_hlo_module(hlo_text)
        result["collectives"] = summarize_collectives(module)
        result["ir_ops"] = module.op_census()
        result["ir_totals"] = module.totals()
        rep = Engine().simulate(module)
        result["engine"] = rep.summary()
        if save_hlo:
            import gzip
            os.makedirs(ART_DIR, exist_ok=True)
            p = os.path.join(ART_DIR, f"{arch}.{shape_name}.{result['mesh']}.hlo.gz")
            with gzip.open(p, "wt") as f:
                f.write(hlo_text)
    except Exception as e:   # parser still in bring-up for exotic ops
        result["collectives"] = {"error": repr(e)}

    if not quiet:
        print(f"[dryrun] {arch} {shape_name} mesh={result['mesh']} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"per_dev={result['memory']['per_device_bytes']/2**30:.2f}GiB")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={cost.get('flops'):.3e} "
              f"bytes={cost.get('bytes accessed'):.3e}")
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(
            ART_DIR, f"{arch}.{shape_name}.{result['mesh']}.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-lenet", action="store_true", default=True)
    args = ap.parse_args()

    failures = []
    cells = []
    if args.all:
        for entry, shape, _ in C.iter_cells():
            if entry.arch_id == "lenet":
                continue
            cells.append((entry.arch_id, shape.name))
    else:
        shapes = [args.shape] if args.shape else [s.name for s in C.STANDARD_SHAPES]
        cells = [(args.arch, s) for s in shapes]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    for arch, shape_name in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape_name, mp, save_hlo=args.save_hlo)
            except Exception:
                traceback.print_exc()
                failures.append((arch, shape_name, mp))
            gc.collect()

    if failures:
        print(f"FAILED cells: {failures}")
        return 1
    print("dry-run OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
