"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        [--shape train_4k] [--steps N] [--smoke] [--multi-pod]

--smoke uses the reduced config + tiny shapes on local devices (CI path);
the full path expects a real TPU slice whose device count matches the mesh.
"""
import argparse

from repro import config as C
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    entry = C.get(args.arch)
    if args.smoke:
        model = entry.smoke
        shape = C.ShapeConfig("smoke_train", 64, 4, "train")
        mesh_cfg = C.SMOKE_MESH
        use_mesh = False
    else:
        model = entry.full
        shape = C.SHAPES_BY_NAME[args.shape]
        mesh_cfg = C.MULTI_POD_MESH if args.multi_pod else C.SINGLE_POD_MESH
        use_mesh = True
    train = C.TrainConfig(total_steps=args.steps or 100,
                          checkpoint_dir=args.ckpt_dir,
                          accum_steps=entry.accum_steps)
    rc = C.RunConfig(model=model, shape=shape, mesh=mesh_cfg, train=train)
    report = Trainer(rc, use_mesh=use_mesh).train()
    print(f"done: steps={report.steps_done} final_loss={report.final_loss:.4f} "
          f"restarts={report.restarts}")


if __name__ == "__main__":
    main()
