"""Serving launcher: loads (or initializes) params and serves batched
requests from the synthetic prompt stream.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke
"""
import argparse

import jax

from repro import config as C
from repro.models import build_model
from repro.runtime.server import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    entry = C.get(args.arch)
    model_cfg = entry.smoke if args.smoke else entry.full
    shape = C.ShapeConfig("serve", args.prompt_len + args.max_new,
                          args.batch, "prefill")
    rc = C.RunConfig(model=model_cfg, shape=shape, mesh=C.SMOKE_MESH)
    model = build_model(model_cfg)
    params = model.init(jax.random.key(0))
    server = Server(rc, params, temperature=0.7)
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 model_cfg.vocab_size)
    batch = {"tokens": prompts}
    if model_cfg.frontend != "none":
        import jax.numpy as jnp
        batch["frontend_emb"] = jax.random.normal(
            jax.random.key(2), (args.batch, model_cfg.frontend_seq,
                                model_cfg.d_model), jnp.bfloat16)
    out = server.generate(batch, max_new_tokens=args.max_new)
    print(f"generated {out.shape} tokens; "
          f"decode {server.stats.decode_tok_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
