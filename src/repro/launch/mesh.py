"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — device count is locked
on first jax init, and only dryrun.py forces the 512-device host platform.
"""
from __future__ import annotations

import jax

from repro.config import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
