"""Shared order-statistics helpers: the ONE quantile implementation.

Before this module the repo grew quantile math wherever a percentile was
needed — ``cluster/events.py`` carried its own linear-interpolation pair
(``percentile`` / ``_percentile_sorted``) and the validate/timelapse
layers were about to add more.  The duplicated versions disagreed on edge
cases: ``q`` outside ``[0, 1]`` indexed past the end of the list (an
``IndexError`` for ``q > 1 + 1/(n-1)``) or silently *extrapolated* below
the minimum for negative ``q`` (``int()`` truncates toward zero, so the
interpolation weight went negative), and a NaN ``q`` or NaN sample
propagated into every downstream summary.

This module is the single source of truth, with the defensible contract:

* ``q`` is **clamped** to ``[0, 1]`` — ``quantile(xs, 1.5)`` is the max,
  ``quantile(xs, -2)`` the min (percentile requests out of range are a
  caller bug, but the least-surprising answer is the nearest order
  statistic, never an extrapolated value outside the sample's range);
* a NaN ``q`` or a NaN sample raises ``ValueError`` instead of silently
  poisoning the result;
* between the order statistics the estimate linearly interpolates
  (numpy's default, what the legacy implementation meant to do).

Dependency-free leaf (stdlib only), importable from every layer without
cycles.
"""
from __future__ import annotations

import math
from typing import Sequence


def quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile of an unsorted sample.

    ``q`` is clamped to [0, 1]; NaN ``q`` or NaN samples raise
    ``ValueError``.  An empty sample returns 0.0 (the legacy convention —
    summaries of empty runs stay well-defined).
    """
    return quantile_sorted(sorted(values), q, _validated=False)


def quantile_sorted(xs: Sequence[float], q: float,
                    _validated: bool = False) -> float:
    """:func:`quantile` over an ALREADY-sorted sequence (no re-sort).

    ``_validated=True`` skips the per-sample NaN scan for hot paths that
    already guarantee NaN-free input (note: ``sorted()`` on a list
    containing NaN does NOT raise, it silently misorders — so the scan is
    on by default).
    """
    if math.isnan(q):
        raise ValueError("quantile q must not be NaN")
    if not _validated and any(math.isnan(x) for x in xs):
        raise ValueError("quantile input contains NaN")
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    q = min(max(q, 0.0), 1.0)
    pos = (len(xs) - 1) * q
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
