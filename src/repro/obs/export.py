"""Shared Chrome Trace Event Format plumbing + the ASCII shade ramp.

Before this module, :mod:`repro.analysis.export`, :mod:`repro.cluster.
export`, and :mod:`repro.core.trace` each hand-rolled the same raw event
dicts (``ph: M/X/C/i``, ``ts``/``dur`` in microseconds, the 0.01 µs
minimum-visible duration).  Those call sites now build events through the
four constructors here, which is what lets engine op lanes, fleet device
tracks, simulator-self spans (:mod:`repro.obs.trace`), and time-lapse
counter tracks (:mod:`repro.obs.timelapse`) compose into **one** trace
file: identical field conventions, distinct ``pid``/``tid`` namespaces.

pid convention: ``pid 0`` = simulated time (engine ops, fleet slices,
time-lapse counters); ``pid 1`` (:data:`~repro.obs.trace.SELF_PID`) =
simulator wall-clock (spans).  Chrome/Perfetto renders pids as separate
process groups, so the two clock domains never visually interleave.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

#: minimum rendered duration in µs — chrome://tracing drops true-zero slices
MIN_DUR_US = 0.01

#: occupancy shade ramp shared by every ASCII renderer (0.0 -> ' ',
#: 1.0 -> '@'); analysis phase rows, fleet device rows, and time-lapse
#: heat strips all draw from this one ramp
SHADES = " .:-=+*#%@"


def shade(value: float) -> str:
    """Map an occupancy fraction in [0, 1] to one :data:`SHADES` glyph."""
    idx = int(max(value, 0.0) * (len(SHADES) - 1))
    return SHADES[min(idx, len(SHADES) - 1)]


def thread_meta(name: str, tid: int, pid: int = 0) -> Dict[str, Any]:
    """``ph: M`` metadata event naming a track (thread) in the viewer."""
    return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


def duration_event(name: str, cat: str, start_s: float, dur_s: float,
                   tid: int, pid: int = 0,
                   args: Optional[Dict[str, Any]] = None,
                   **extra: Any) -> Dict[str, Any]:
    """``ph: X`` complete event; seconds in, µs out, floor-clamped dur.

    ``extra`` passes through rarely-used raw fields (e.g. ``cname``)."""
    ev: Dict[str, Any] = {"name": name, "cat": cat, "ph": "X",
                          "ts": start_s * 1e6,
                          "dur": max(dur_s * 1e6, MIN_DUR_US),
                          "pid": pid, "tid": tid}
    if args is not None:
        ev["args"] = args
    ev.update(extra)
    return ev


def counter_event(name: str, cat: str, t_s: float,
                  values: Dict[str, Any], pid: int = 0,
                  tid: Optional[int] = None) -> Dict[str, Any]:
    """``ph: C`` counter sample (one stacked-area track per name)."""
    ev: Dict[str, Any] = {"name": name, "cat": cat, "ph": "C",
                          "ts": t_s * 1e6, "pid": pid, "args": values}
    if tid is not None:
        ev["tid"] = tid
    return ev


def instant_event(name: str, cat: str, t_s: float, tid: int, pid: int = 0,
                  args: Optional[Dict[str, Any]] = None,
                  scope: str = "g") -> Dict[str, Any]:
    """``ph: i`` instant marker (global scope by default: full-height line)."""
    ev: Dict[str, Any] = {"name": name, "cat": cat, "ph": "i", "s": scope,
                          "ts": t_s * 1e6, "pid": pid, "tid": tid}
    if args is not None:
        ev["args"] = args
    return ev


def trace_json(events: List[dict], *more: List[dict]) -> str:
    """Wrap event lists (concatenated in order) as a Trace Event JSON doc.

    This is the compose point: ``trace_json(op_events, span_events,
    lapse_events)`` yields one file with every track."""
    merged: List[dict] = list(events)
    for lst in more:
        merged.extend(lst)
    return json.dumps({"traceEvents": merged, "displayTimeUnit": "ns"})
