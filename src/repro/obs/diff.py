"""Manifest diffing: the regression attributor behind ``repro.obs diff``.

Given two :class:`~repro.obs.manifest.RunManifest` files, report *what*
changed (config knobs), *how much* each metric moved, *which simulator
layer* each moved metric belongs to (engine / memory / topology /
cluster / faults — inferred from the metric name), and *where in time*
the runs diverged (the first / worst time-lapse intervals whose series
disagree).  This is the paper's time-lapse methodology turned into a
regression tool: instead of eyeballing two AerialVision plots, the diff
names the interval and the series that moved.

Exit-code contract (used by the CI smoke step): identical manifests diff
empty; a single-knob change (``--policy fifo`` vs ``sjf``) must surface
that knob under config changes and the affected metrics under deltas.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.manifest import RunManifest

#: metric-name prefixes/substrings -> owning simulator layer, first match
#: wins (order matters: "exposed_ici_seconds" is topology, not engine)
_LAYER_RULES: Tuple[Tuple[str, str], ...] = (
    ("channel_", "memory"), ("peak_hbm", "memory"), ("spill_", "memory"),
    ("hbm_utilization", "memory"),
    ("link_", "topology"), ("ici_seconds", "topology"),
    ("exposed_ici", "topology"), ("total_ici_bytes", "topology"),
    ("unit_ici", "topology"),
    ("failure", "faults"), ("recover", "faults"), ("checkpoint", "faults"),
    ("restore", "faults"), ("lost_work", "faults"), ("reshape", "faults"),
    ("goodput", "faults"),
    ("queue", "cluster"), ("latency", "cluster"), ("hol_", "cluster"),
    ("makespan", "cluster"), ("fleet_", "cluster"), ("cache_", "cluster"),
    ("utilization", "cluster"), ("num_devices", "cluster"),
    ("num_jobs", "cluster"), ("preempt", "cluster"),
    ("cold_start", "cluster"),
)


def metric_layer(name: str) -> str:
    """Which simulator layer owns a summary metric, by naming convention."""
    for needle, layer in _LAYER_RULES:
        if needle in name:
            return layer
    return "engine"


def _rel(a: float, b: float) -> float:
    """Relative delta; ±inf for a zero baseline (0 -> nonzero)."""
    if a == 0.0:
        return 0.0 if b == 0.0 else math.copysign(math.inf, b)
    return (b - a) / abs(a)


def _fmt_rel(rel: float) -> str:
    return f"{rel:+.2%}" if math.isfinite(rel) else "was 0"


@dataclass
class MetricDelta:
    """One summary metric that moved between the two runs."""

    name: str
    a: float
    b: float
    layer: str

    @property
    def abs_delta(self) -> float:
        return self.b - self.a

    @property
    def rel_delta(self) -> float:
        """(b - a) / |a|; ±inf when the baseline is exactly zero."""
        return _rel(self.a, self.b)

    def render(self) -> str:
        return (f"{self.name:<40s} {self.a:>14.6g} -> {self.b:<14.6g} "
                f"({_fmt_rel(self.rel_delta)}) [{self.layer}]")


@dataclass
class LapseDivergence:
    """One time-lapse interval/series where the two runs disagree."""

    index: int
    t0: float
    series: str                  # e.g. "busy_mxu", "queue_depth"
    a: float
    b: float

    @property
    def rel_delta(self) -> float:
        return _rel(self.a, self.b)

    def render(self) -> str:
        return (f"interval {self.index:>4d} @ {self.t0:.4g}s  "
                f"{self.series:<24s} {self.a:.6g} -> {self.b:.6g} "
                f"({_fmt_rel(self.rel_delta)})")


@dataclass
class ManifestDiff:
    """Structured comparison of two run manifests."""

    a_label: str
    b_label: str
    identical_digest: bool
    kind_mismatch: Optional[Tuple[str, str]] = None
    config_changes: Dict[str, Tuple[Any, Any]] = field(default_factory=dict)
    seed_changes: Dict[str, Tuple[Any, Any]] = field(default_factory=dict)
    metric_deltas: List[MetricDelta] = field(default_factory=list)
    lapse_divergences: List[LapseDivergence] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        """True when the runs are indistinguishable (the self-diff case)."""
        return (self.kind_mismatch is None and not self.config_changes
                and not self.seed_changes and not self.metric_deltas
                and not self.lapse_divergences)

    def layers(self) -> Dict[str, int]:
        """Moved-metric count per simulator layer (the attribution)."""
        out: Dict[str, int] = {}
        for d in self.metric_deltas:
            out[d.layer] = out.get(d.layer, 0) + 1
        return out

    def to_doc(self) -> Dict[str, Any]:
        return {
            "a": self.a_label, "b": self.b_label, "empty": self.empty,
            "identical_digest": self.identical_digest,
            "kind_mismatch": list(self.kind_mismatch)
            if self.kind_mismatch else None,
            "config_changes": {k: list(v)
                               for k, v in self.config_changes.items()},
            "seed_changes": {k: list(v)
                             for k, v in self.seed_changes.items()},
            "layers": self.layers(),
            "metric_deltas": [{
                "name": d.name, "a": d.a, "b": d.b, "layer": d.layer,
                "abs_delta": d.abs_delta,
                # None, not Infinity: keep the doc strict-JSON
                "rel_delta": d.rel_delta
                if math.isfinite(d.rel_delta) else None,
            } for d in self.metric_deltas],
            "lapse_divergences": [{
                "index": d.index, "t0": d.t0, "series": d.series,
                "a": d.a, "b": d.b,
                "rel_delta": d.rel_delta
                if math.isfinite(d.rel_delta) else None,
            } for d in self.lapse_divergences],
        }

    def render(self, top: int = 12) -> str:
        lines = [f"diff: {self.a_label!r} vs {self.b_label!r}"]
        if self.kind_mismatch:
            lines.append(f"  KIND MISMATCH: {self.kind_mismatch[0]} vs "
                         f"{self.kind_mismatch[1]} — not comparable")
            return "\n".join(lines)
        if self.empty:
            lines.append("  identical: no config, seed, metric, or "
                         "time-lapse differences")
            return "\n".join(lines)
        if self.config_changes:
            lines.append("  config changes:")
            for k, (va, vb) in sorted(self.config_changes.items()):
                lines.append(f"    {k:<24s} {va!r} -> {vb!r}")
        if self.seed_changes:
            lines.append("  seed changes:")
            for k, (va, vb) in sorted(self.seed_changes.items()):
                lines.append(f"    {k:<24s} {va!r} -> {vb!r}")
        if self.metric_deltas:
            layers = ", ".join(f"{l}: {n}"
                               for l, n in sorted(self.layers().items()))
            lines.append(f"  metric deltas ({len(self.metric_deltas)} "
                         f"moved; by layer — {layers}):")
            for d in self.metric_deltas[:top]:
                lines.append("    " + d.render())
            if len(self.metric_deltas) > top:
                lines.append(f"    ... {len(self.metric_deltas) - top} "
                             f"more (use --top)")
        if self.lapse_divergences:
            lines.append(f"  time-lapse divergences "
                         f"({len(self.lapse_divergences)} intervals; "
                         f"first/worst shown):")
            for d in self.lapse_divergences[:top]:
                lines.append("    " + d.render())
            if len(self.lapse_divergences) > top:
                lines.append(f"    ... {len(self.lapse_divergences) - top} "
                             f"more")
        return "\n".join(lines)


def _lapse_series(doc: Dict[str, Any]) -> Dict[int, Dict[str, float]]:
    """Flatten a TimeLapse doc into {interval: {series: value}}."""
    out: Dict[int, Dict[str, float]] = {}
    for i, iv in enumerate(doc.get("intervals", [])):
        row: Dict[str, float] = {}
        for k, v in iv.get("busy_seconds", {}).items():
            row[f"busy_{k}"] = v
        for c, v in enumerate(iv.get("channel_busy", [])):
            row[f"channel_{c}"] = v
        for l, v in iv.get("link_busy", {}).items():
            row[f"link_{l}"] = v
        if iv.get("camping_seconds"):
            row["camping_seconds"] = iv["camping_seconds"]
        if iv.get("queue_depth"):
            row["queue_depth"] = iv["queue_depth"]
        out[i] = row
    return out


def diff_manifests(a: RunManifest, b: RunManifest,
                   rel_tol: float = 1e-9,
                   abs_tol: float = 1e-12) -> ManifestDiff:
    """Compare two manifests; values within tolerance are *not* reported.

    ``rel_tol`` is deliberately tiny by default: the simulators are
    deterministic, so a same-seed same-knob pair must diff empty without
    any forgiveness window, while FP-noise-level differences between
    hosts can be absorbed by raising it (``--rel-tol``).
    """
    d = ManifestDiff(a.label or "a", b.label or "b",
                     identical_digest=(a.digest == b.digest))
    if a.kind != b.kind:
        d.kind_mismatch = (a.kind, b.kind)
        return d

    def _close(va: float, vb: float) -> bool:
        return abs(vb - va) <= max(abs_tol, rel_tol * max(abs(va), abs(vb)))

    for k in sorted(set(a.config) | set(b.config)):
        va, vb = a.config.get(k), b.config.get(k)
        if va != vb:
            d.config_changes[k] = (va, vb)
    for k in sorted(set(a.seeds) | set(b.seeds)):
        va, vb = a.seeds.get(k), b.seeds.get(k)
        if va != vb:
            d.seed_changes[k] = (va, vb)

    for k in sorted(set(a.metrics) | set(b.metrics)):
        va, vb = a.metrics.get(k, 0.0), b.metrics.get(k, 0.0)
        if not _close(va, vb):
            d.metric_deltas.append(MetricDelta(k, va, vb, metric_layer(k)))
    d.metric_deltas.sort(key=lambda m: abs(m.rel_delta), reverse=True)

    if a.timelapse and b.timelapse:
        sa, sb = _lapse_series(a.timelapse), _lapse_series(b.timelapse)
        for i in sorted(set(sa) | set(sb)):
            ra, rb = sa.get(i, {}), sb.get(i, {})
            t0 = (a.timelapse.get("intervals", [{}] * (i + 1))[i]
                  .get("t0", 0.0)) if i < len(
                a.timelapse.get("intervals", [])) else 0.0
            for series in sorted(set(ra) | set(rb)):
                va, vb = ra.get(series, 0.0), rb.get(series, 0.0)
                if not _close(va, vb):
                    d.lapse_divergences.append(
                        LapseDivergence(i, t0, series, va, vb))
        d.lapse_divergences.sort(
            key=lambda x: (abs(x.rel_delta), x.index), reverse=True)
    return d
