"""Manifest diffing: the regression attributor behind ``repro.obs diff``.

Given two :class:`~repro.obs.manifest.RunManifest` files, report *what*
changed (config knobs), *how much* each metric moved, *which simulator
layer* each moved metric belongs to (engine / memory / topology /
cluster / faults — inferred from the metric name), and *where in time*
the runs diverged (the first / worst time-lapse intervals whose series
disagree).  This is the paper's time-lapse methodology turned into a
regression tool: instead of eyeballing two AerialVision plots, the diff
names the interval and the series that moved.

Exit-code contract (used by the CI smoke step): identical manifests diff
empty; a single-knob change (``--policy fifo`` vs ``sjf``) must surface
that knob under config changes and the affected metrics under deltas.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.manifest import RunManifest

#: metric-name prefixes/substrings -> owning simulator layer, first match
#: wins (order matters: "exposed_ici_seconds" is topology, not engine)
_LAYER_RULES: Tuple[Tuple[str, str], ...] = (
    ("channel_", "memory"), ("peak_hbm", "memory"), ("spill_", "memory"),
    ("hbm_utilization", "memory"),
    ("link_", "topology"), ("ici_seconds", "topology"),
    ("exposed_ici", "topology"), ("total_ici_bytes", "topology"),
    ("unit_ici", "topology"),
    ("failure", "faults"), ("recover", "faults"), ("checkpoint", "faults"),
    ("restore", "faults"), ("lost_work", "faults"), ("reshape", "faults"),
    ("goodput", "faults"),
    ("queue", "cluster"), ("latency", "cluster"), ("hol_", "cluster"),
    ("makespan", "cluster"), ("fleet_", "cluster"), ("cache_", "cluster"),
    ("utilization", "cluster"), ("num_devices", "cluster"),
    ("num_jobs", "cluster"), ("preempt", "cluster"),
    ("cold_start", "cluster"),
)


def metric_layer(name: str) -> str:
    """Which simulator layer owns a summary metric, by naming convention."""
    for needle, layer in _LAYER_RULES:
        if needle in name:
            return layer
    return "engine"


def _rel(a: float, b: float) -> float:
    """Relative delta; ±inf for a zero baseline (0 -> nonzero)."""
    if a == 0.0:
        return 0.0 if b == 0.0 else math.copysign(math.inf, b)
    return (b - a) / abs(a)


def _fmt_rel(rel: float) -> str:
    return f"{rel:+.2%}" if math.isfinite(rel) else "was 0"


@dataclass
class MetricDelta:
    """One summary metric that moved between the two runs."""

    name: str
    a: float
    b: float
    layer: str

    @property
    def abs_delta(self) -> float:
        return self.b - self.a

    @property
    def rel_delta(self) -> float:
        """(b - a) / |a|; ±inf when the baseline is exactly zero."""
        return _rel(self.a, self.b)

    def render(self) -> str:
        return (f"{self.name:<40s} {self.a:>14.6g} -> {self.b:<14.6g} "
                f"({_fmt_rel(self.rel_delta)}) [{self.layer}]")


@dataclass
class LapseDivergence:
    """One time-lapse interval/series where the two runs disagree."""

    index: int
    t0: float
    series: str                  # e.g. "busy_mxu", "queue_depth"
    a: float
    b: float

    @property
    def rel_delta(self) -> float:
        return _rel(self.a, self.b)

    def render(self) -> str:
        return (f"interval {self.index:>4d} @ {self.t0:.4g}s  "
                f"{self.series:<24s} {self.a:.6g} -> {self.b:.6g} "
                f"({_fmt_rel(self.rel_delta)})")


@dataclass
class ManifestDiff:
    """Structured comparison of two run manifests."""

    a_label: str
    b_label: str
    identical_digest: bool
    kind_mismatch: Optional[Tuple[str, str]] = None
    config_changes: Dict[str, Tuple[Any, Any]] = field(default_factory=dict)
    seed_changes: Dict[str, Tuple[Any, Any]] = field(default_factory=dict)
    metric_deltas: List[MetricDelta] = field(default_factory=list)
    lapse_divergences: List[LapseDivergence] = field(default_factory=list)
    #: set when the two lapses had different interval counts and were
    #: resampled to the coarser grid before comparison
    lapse_note: str = ""

    @property
    def empty(self) -> bool:
        """True when the runs are indistinguishable (the self-diff case)."""
        return (self.kind_mismatch is None and not self.config_changes
                and not self.seed_changes and not self.metric_deltas
                and not self.lapse_divergences)

    def layers(self) -> Dict[str, int]:
        """Moved-metric count per simulator layer (the attribution)."""
        out: Dict[str, int] = {}
        for d in self.metric_deltas:
            out[d.layer] = out.get(d.layer, 0) + 1
        return out

    def to_doc(self) -> Dict[str, Any]:
        return {
            "a": self.a_label, "b": self.b_label, "empty": self.empty,
            "identical_digest": self.identical_digest,
            "kind_mismatch": list(self.kind_mismatch)
            if self.kind_mismatch else None,
            "config_changes": {k: list(v)
                               for k, v in self.config_changes.items()},
            "seed_changes": {k: list(v)
                             for k, v in self.seed_changes.items()},
            "layers": self.layers(),
            "metric_deltas": [{
                "name": d.name, "a": d.a, "b": d.b, "layer": d.layer,
                "abs_delta": d.abs_delta,
                # None, not Infinity: keep the doc strict-JSON
                "rel_delta": d.rel_delta
                if math.isfinite(d.rel_delta) else None,
            } for d in self.metric_deltas],
            "lapse_divergences": [{
                "index": d.index, "t0": d.t0, "series": d.series,
                "a": d.a, "b": d.b,
                "rel_delta": d.rel_delta
                if math.isfinite(d.rel_delta) else None,
            } for d in self.lapse_divergences],
            "lapse_note": self.lapse_note or None,
        }

    def render(self, top: int = 12) -> str:
        lines = [f"diff: {self.a_label!r} vs {self.b_label!r}"]
        if self.kind_mismatch:
            lines.append(f"  KIND MISMATCH: {self.kind_mismatch[0]} vs "
                         f"{self.kind_mismatch[1]} — not comparable")
            return "\n".join(lines)
        if self.empty:
            lines.append("  identical: no config, seed, metric, or "
                         "time-lapse differences")
            if self.lapse_note:
                lines.append(f"  note: {self.lapse_note}")
            return "\n".join(lines)
        if self.config_changes:
            lines.append("  config changes:")
            for k, (va, vb) in sorted(self.config_changes.items()):
                lines.append(f"    {k:<24s} {va!r} -> {vb!r}")
        if self.seed_changes:
            lines.append("  seed changes:")
            for k, (va, vb) in sorted(self.seed_changes.items()):
                lines.append(f"    {k:<24s} {va!r} -> {vb!r}")
        if self.metric_deltas:
            layers = ", ".join(f"{l}: {n}"
                               for l, n in sorted(self.layers().items()))
            lines.append(f"  metric deltas ({len(self.metric_deltas)} "
                         f"moved; by layer — {layers}):")
            for d in self.metric_deltas[:top]:
                lines.append("    " + d.render())
            if len(self.metric_deltas) > top:
                lines.append(f"    ... {len(self.metric_deltas) - top} "
                             f"more (use --top)")
        if self.lapse_note:
            lines.append(f"  note: {self.lapse_note}")
        if self.lapse_divergences:
            lines.append(f"  time-lapse divergences "
                         f"({len(self.lapse_divergences)} intervals; "
                         f"first/worst shown):")
            for d in self.lapse_divergences[:top]:
                lines.append("    " + d.render())
            if len(self.lapse_divergences) > top:
                lines.append(f"    ... {len(self.lapse_divergences) - top} "
                             f"more")
        return "\n".join(lines)


def resample_lapse_doc(doc: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Rebucket a TimeLapse doc onto ``n`` equal intervals of the same
    span.  Additive series (busy/channel/link/camping/ops) distribute by
    proportional overlap — exactly the smearing ``TimeLapse.from_report``
    uses, so resampling a fine grid reproduces the coarse grid up to FP;
    ``queue_depth`` (a mean) is width-weighted.  Used by
    :func:`diff_manifests` when two manifests were produced with
    different ``--lapse-intervals`` counts.
    """
    intervals = doc.get("intervals", [])
    if not intervals or n <= 0 or len(intervals) == n:
        return doc
    end = max(intervals[-1].get("t1", 0.0), 1e-12)
    width = end / n
    out = [{"t0": i * width, "t1": (i + 1) * width, "busy_seconds": {},
            "channel_busy": [], "link_busy": {}, "camping_seconds": 0.0,
            "ops_retired": 0.0, "queue_depth": 0.0} for i in range(n)]
    for iv in intervals:
        t0, t1 = iv.get("t0", 0.0), iv.get("t1", 0.0)
        w = t1 - t0
        if w <= 0:
            continue
        b0 = min(int(t0 / width), n - 1)
        b1 = min(int(t1 / width), n - 1)
        for bi in range(b0, b1 + 1):
            o = out[bi]
            ov = max(min(t1, o["t1"]) - max(t0, o["t0"]), 0.0)
            frac = ov / w
            if frac <= 0 and b0 != b1:
                continue
            if b0 == b1:
                frac, ov = 1.0, w    # guard FP loss: fits one bucket
            for k, v in iv.get("busy_seconds", {}).items():
                o["busy_seconds"][k] = o["busy_seconds"].get(k, 0.0) \
                    + v * frac
            cb = iv.get("channel_busy", [])
            if cb:
                if len(o["channel_busy"]) < len(cb):
                    o["channel_busy"].extend(
                        [0.0] * (len(cb) - len(o["channel_busy"])))
                for c, v in enumerate(cb):
                    o["channel_busy"][c] += v * frac
            for l, v in iv.get("link_busy", {}).items():
                o["link_busy"][l] = o["link_busy"].get(l, 0.0) + v * frac
            o["camping_seconds"] += iv.get("camping_seconds", 0.0) * frac
            o["ops_retired"] += iv.get("ops_retired", 0.0) * frac
            o["queue_depth"] += iv.get("queue_depth", 0.0) * ov / width
    for o in out:
        cb = o["channel_busy"]
        mean = sum(cb) / len(cb) if cb else 0.0
        o["channel_imbalance"] = max(cb) / mean if mean > 0 else 1.0
    return {**doc, "num_intervals": n, "intervals": out}


def _lapse_series(doc: Dict[str, Any]) -> Dict[int, Dict[str, float]]:
    """Flatten a TimeLapse doc into {interval: {series: value}}."""
    out: Dict[int, Dict[str, float]] = {}
    for i, iv in enumerate(doc.get("intervals", [])):
        row: Dict[str, float] = {}
        for k, v in iv.get("busy_seconds", {}).items():
            row[f"busy_{k}"] = v
        for c, v in enumerate(iv.get("channel_busy", [])):
            row[f"channel_{c}"] = v
        for l, v in iv.get("link_busy", {}).items():
            row[f"link_{l}"] = v
        if iv.get("camping_seconds"):
            row["camping_seconds"] = iv["camping_seconds"]
        if iv.get("queue_depth"):
            row["queue_depth"] = iv["queue_depth"]
        out[i] = row
    return out


def diff_manifests(a: RunManifest, b: RunManifest,
                   rel_tol: float = 1e-9,
                   abs_tol: float = 1e-12) -> ManifestDiff:
    """Compare two manifests; values within tolerance are *not* reported.

    ``rel_tol`` is deliberately tiny by default: the simulators are
    deterministic, so a same-seed same-knob pair must diff empty without
    any forgiveness window, while FP-noise-level differences between
    hosts can be absorbed by raising it (``--rel-tol``).
    """
    d = ManifestDiff(a.label or "a", b.label or "b",
                     identical_digest=(a.digest == b.digest))
    if a.kind != b.kind:
        d.kind_mismatch = (a.kind, b.kind)
        return d

    def _close(va: float, vb: float) -> bool:
        return abs(vb - va) <= max(abs_tol, rel_tol * max(abs(va), abs(vb)))

    for k in sorted(set(a.config) | set(b.config)):
        va, vb = a.config.get(k), b.config.get(k)
        if va != vb:
            d.config_changes[k] = (va, vb)
    for k in sorted(set(a.seeds) | set(b.seeds)):
        va, vb = a.seeds.get(k), b.seeds.get(k)
        if va != vb:
            d.seed_changes[k] = (va, vb)

    for k in sorted(set(a.metrics) | set(b.metrics)):
        va, vb = a.metrics.get(k, 0.0), b.metrics.get(k, 0.0)
        if not _close(va, vb):
            d.metric_deltas.append(MetricDelta(k, va, vb, metric_layer(k)))
    d.metric_deltas.sort(key=lambda m: abs(m.rel_delta), reverse=True)

    if a.timelapse and b.timelapse:
        la, lb = a.timelapse, b.timelapse
        na = len(la.get("intervals", []))
        nb = len(lb.get("intervals", []))
        if na != nb and na > 0 and nb > 0:
            # different --lapse-intervals counts: degrade gracefully by
            # resampling both onto the coarser grid instead of failing
            # the interval-by-interval compare
            n = min(na, nb)
            la, lb = resample_lapse_doc(la, n), resample_lapse_doc(lb, n)
            d.lapse_note = (f"time-lapse grids differ ({na} vs {nb} "
                            f"intervals); both resampled to the coarser "
                            f"{n}-interval grid before comparison")
        sa, sb = _lapse_series(la), _lapse_series(lb)
        for i in sorted(set(sa) | set(sb)):
            ra, rb = sa.get(i, {}), sb.get(i, {})
            t0 = (la.get("intervals", [{}] * (i + 1))[i]
                  .get("t0", 0.0)) if i < len(
                la.get("intervals", [])) else 0.0
            for series in sorted(set(ra) | set(rb)):
                va, vb = ra.get(series, 0.0), rb.get(series, 0.0)
                if not _close(va, vb):
                    d.lapse_divergences.append(
                        LapseDivergence(i, t0, series, va, vb))
        d.lapse_divergences.sort(
            key=lambda x: (abs(x.rel_delta), x.index), reverse=True)
    return d
