"""Shared pathology thresholds for heat strips, link tables and the doctor.

Before this module, the camping cutoff lived twice: ``obs/timelapse.py``
marked intervals with ``!`` above a hard-coded 1.5 channel-imbalance, and
``analysis/links.py`` flagged camped fabrics above its own hard-coded 1.5
link-imbalance.  The doctor (``repro.obs.doctor``) adds a third consumer,
so the cutoffs are hoisted here: one frozen :class:`Thresholds` config that
every verdict reads, guaranteeing the doctor can never disagree with the
heat strips about what counts as camped.

The module is a dependency-free leaf (stdlib only), so both ``obs`` and
``analysis`` can import it without cycles.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Thresholds:
    """Detection cutoffs shared by the renderers and the doctor detectors.

    The two imbalance indices are busiest/mean ratios (1.0 = perfectly
    balanced); the ``*_fraction`` fields are shares of the run's total
    makespan below which a pathology is not worth reporting.
    """

    #: per-interval HBM channel-imbalance above this marks the bucket as
    #: camped (an even interleave reads ~1.0; CAMPING_FRACTION=0.25
    #: subsets read >2) — the timelapse "!" marker and the camping detector
    channel_camping_imbalance: float = 1.5
    #: whole-run fabric link-imbalance above this marks the fabric camped
    #: (the links.py table verdict and the link-imbalance detector)
    link_camping_imbalance: float = 1.5
    #: exposed (non-overlapped) collective seconds / total above this
    #: trips the exposed-communication detector
    exposed_comm_fraction: float = 0.02
    #: VMEM spill bytes / total HBM traffic above this trips the detector
    spill_fraction: float = 0.01
    #: launch-overhead seconds / total above this trips the detector
    launch_overhead_fraction: float = 0.10
    #: HoL-blocked jobs / admitted jobs above this trips the detector
    hol_blocked_fraction: float = 0.05
    #: slowest/mean device-busy dilation inside a gang above this trips
    #: the straggler detector
    straggler_dilation: float = 1.2
    #: |interval - Young-Daly optimum| / optimum above this trips the
    #: checkpoint-interval detector
    checkpoint_interval_rel_error: float = 0.25
    #: SimulationCache hit rate below this (with enough lookups) trips
    #: the miss-storm detector
    cache_hit_rate_floor: float = 0.5
    #: findings recovering less than this fraction of the makespan are
    #: dropped (noise floor for the ranked table)
    min_recoverable_fraction: float = 0.005
    #: conservation-law residual (Little's law, busy-time/utilization
    #: identities — ``repro.validate``) above this trips the
    #: accounting-residual detector; identities are exact, so this band
    #: only absorbs float noise on long tapes
    conservation_residual: float = 0.01


#: the one instance every renderer / detector reads by default
DEFAULT_THRESHOLDS = Thresholds()

#: legacy aliases — ``obs/timelapse.py`` and ``analysis/links.py``
#: re-export these under their historic module-level names
CAMPED_THRESHOLD = DEFAULT_THRESHOLDS.channel_camping_imbalance
LINK_CAMPING_THRESHOLD = DEFAULT_THRESHOLDS.link_camping_imbalance
