"""Run manifests: the comparable, diffable record of one simulation.

A report answers "what happened in this run"; a *manifest* makes two runs
answerable against each other — the DeepProf-style question ("which
phase/unit/metric diverged between these runs?") needs the config knobs,
seeds, summary metrics, stage timings, and time-lapse series captured in
one self-describing JSON document.  Both CLIs grow a ``--manifest PATH``
flag writing one of these; ``python -m repro.obs diff a.json b.json``
(:mod:`repro.obs.diff`) consumes them.

The ``digest`` field is a SHA-256 over the canonicalized config+seeds+
metrics, so "are these runs identical?" is one string compare, and a
regression bisect can fingerprint runs without parsing them.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

#: manifest schema version — bump when field semantics change
SCHEMA = 1


def _canonical(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


@dataclass
class RunManifest:
    """One run's identity + results, as written by ``--manifest``."""

    kind: str                              # "engine" | "cluster"
    label: str                             # workload / "trace x policy"
    config: Dict[str, Any] = field(default_factory=dict)   # CLI knobs
    seeds: Dict[str, int] = field(default_factory=dict)
    metrics: Dict[str, float] = field(default_factory=dict)  # summary()
    stage_seconds: Dict[str, float] = field(default_factory=dict)
    timelapse: Optional[Dict[str, Any]] = None   # TimeLapse.to_doc()

    @property
    def digest(self) -> str:
        """SHA-256 fingerprint of config + seeds + metrics (not wall-clock
        stage timings or the lapse — those vary run to run / host to host
        even when the simulation is bit-identical)."""
        payload = _canonical({"kind": self.kind, "label": self.label,
                              "config": self.config, "seeds": self.seeds,
                              "metrics": self.metrics})
        return hashlib.sha256(payload.encode()).hexdigest()

    def to_doc(self) -> Dict[str, Any]:
        return {"schema": SCHEMA, "kind": self.kind, "label": self.label,
                "digest": self.digest, "config": self.config,
                "seeds": self.seeds, "metrics": self.metrics,
                "stage_seconds": self.stage_seconds,
                "timelapse": self.timelapse}

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_doc(), indent=indent, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "RunManifest":
        schema = doc.get("schema", SCHEMA)
        if schema > SCHEMA:
            raise ValueError(
                f"manifest schema {schema} is newer than supported {SCHEMA}")
        return cls(kind=doc.get("kind", "engine"),
                   label=doc.get("label", ""),
                   config=dict(doc.get("config", {})),
                   seeds=dict(doc.get("seeds", {})),
                   metrics=dict(doc.get("metrics", {})),
                   stage_seconds=dict(doc.get("stage_seconds", {})),
                   timelapse=doc.get("timelapse"))

    @classmethod
    def load(cls, path: str) -> "RunManifest":
        with open(path) as f:
            return cls.from_doc(json.load(f))


def engine_manifest(report, config: Dict[str, Any],
                    seeds: Optional[Dict[str, int]] = None,
                    label: str = "",
                    stage_seconds: Optional[Dict[str, float]] = None,
                    timelapse=None) -> RunManifest:
    """Manifest for one engine run (``report`` is a ``SimReport``)."""
    lapse_doc = timelapse.to_doc() if timelapse is not None else None
    metrics = {k: v for k, v in report.summary().items()
               if isinstance(v, (int, float))}
    return RunManifest("engine", label, config=dict(config),
                       seeds=dict(seeds or {}), metrics=metrics,
                       stage_seconds=dict(stage_seconds or {}),
                       timelapse=lapse_doc)


def cluster_manifest(report, config: Dict[str, Any],
                     seeds: Optional[Dict[str, int]] = None,
                     label: str = "",
                     stage_seconds: Optional[Dict[str, float]] = None,
                     timelapse=None,
                     extra_metrics: Optional[Dict[str, float]] = None
                     ) -> RunManifest:
    """Manifest for one fleet run (``report`` is a ``ClusterReport``).

    ``extra_metrics`` merges additional numeric series into the metric
    map — the cluster CLI feeds ``repro.validate`` residuals through it,
    so manifest diffs and the regression sentinel track conservation
    drift like any other metric.
    """
    lapse_doc = timelapse.to_doc() if timelapse is not None else None
    metrics = {k: v for k, v in report.summary().items()
               if isinstance(v, (int, float)) and not isinstance(v, bool)}
    metrics.update(extra_metrics or {})
    return RunManifest(
        "cluster", label or f"{report.trace_name} x {report.policy}",
        config=dict(config), seeds=dict(seeds or {}), metrics=metrics,
        stage_seconds=dict(stage_seconds or {}), timelapse=lapse_doc)
