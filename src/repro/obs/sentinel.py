"""Regression sentinel: gate CI on a committed run-manifest baseline.

``python -m repro.obs diff`` answers "what changed between these runs?";
the sentinel answers the CI question "is this change acceptable?".  It
compares a freshly produced :class:`~repro.obs.manifest.RunManifest`
against a committed baseline under *per-metric* tolerance rules (simulated
metrics are deterministic, so the default tolerance is tight; individual
metrics can be loosened with ``--tol metric=REL``), and maps the verdict
onto the repo's standard exit-code contract:

* ``0`` — clean: every metric within tolerance, same config and seeds;
* ``3`` — regression: a metric left its band, or a config/seed drifted;
* ``2`` — error: unreadable manifest, kind mismatch, bad tolerance spec.

Each run can be appended to a ``BENCH_doctor.json`` trajectory (one entry
per sentinel invocation with the headline metrics and verdict), so the
perf/diagnosis history is tracked across PRs next to ``BENCH_perf.json``.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.manifest import RunManifest

#: trajectory file schema version
TRAJECTORY_SCHEMA = 1

#: default per-metric relative tolerance — tight because the simulators
#: are deterministic; host-FP noise sits far below this
DEFAULT_TOLERANCE = 1e-6

#: headline metrics copied into each trajectory entry (when present)
HEADLINE_METRICS = ("total_seconds", "mfu", "hbm_utilization",
                    "channel_imbalance", "link_imbalance", "makespan_s",
                    "goodput_fraction", "mean_queue_delay_s")


def parse_tolerances(specs: List[str]) -> Dict[str, float]:
    """Parse repeated ``--tol metric=REL`` specs into a rule map."""
    out: Dict[str, float] = {}
    for spec in specs or []:
        name, eq, val = spec.partition("=")
        if not eq or not name:
            raise ValueError(f"bad tolerance spec {spec!r} "
                             "(expected metric=REL, e.g. mfu=0.05)")
        try:
            rel = float(val)
        except ValueError:
            raise ValueError(f"bad tolerance value in {spec!r}")
        if rel < 0:
            raise ValueError(f"negative tolerance in {spec!r}")
        out[name] = rel
    return out


@dataclass
class MetricVerdict:
    """One metric checked against its tolerance band."""

    name: str
    baseline: float
    fresh: float
    tolerance: float
    ok: bool

    @property
    def rel_delta(self) -> float:
        if self.baseline == 0.0:
            return 0.0 if self.fresh == 0.0 else math.inf
        return (self.fresh - self.baseline) / abs(self.baseline)

    def render(self) -> str:
        rel = self.rel_delta
        rel_s = f"{rel:+.3%}" if math.isfinite(rel) else "was 0"
        flag = "ok" if self.ok else "REGRESSED"
        return (f"{self.name:<36s} {self.baseline:>13.6g} -> "
                f"{self.fresh:<13.6g} ({rel_s}; tol {self.tolerance:g}) "
                f"{flag}")


@dataclass
class SentinelReport:
    """Verdict of one baseline-vs-fresh comparison."""

    baseline_label: str
    fresh_label: str
    verdicts: List[MetricVerdict] = field(default_factory=list)
    config_changes: Dict[str, Any] = field(default_factory=dict)
    seed_changes: Dict[str, Any] = field(default_factory=dict)
    identical_digest: bool = False

    @property
    def regressions(self) -> List[MetricVerdict]:
        return [v for v in self.verdicts if not v.ok]

    @property
    def clean(self) -> bool:
        return (not self.regressions and not self.config_changes
                and not self.seed_changes)

    def to_doc(self) -> Dict[str, Any]:
        return {"baseline": self.baseline_label, "fresh": self.fresh_label,
                "clean": self.clean,
                "identical_digest": self.identical_digest,
                "config_changes": dict(self.config_changes),
                "seed_changes": dict(self.seed_changes),
                "regressions": [{
                    "name": v.name, "baseline": v.baseline,
                    "fresh": v.fresh, "tolerance": v.tolerance,
                    "rel_delta": v.rel_delta
                    if math.isfinite(v.rel_delta) else None,
                } for v in self.regressions]}

    def render(self, verbose: bool = False) -> str:
        lines = [f"sentinel: {self.fresh_label!r} vs baseline "
                 f"{self.baseline_label!r} — "
                 f"{'CLEAN' if self.clean else 'REGRESSION'}"]
        if self.identical_digest:
            lines.append("  identical digest (bit-identical run)")
        for k, (va, vb) in sorted(self.config_changes.items()):
            lines.append(f"  config drift: {k} {va!r} -> {vb!r}")
        for k, (va, vb) in sorted(self.seed_changes.items()):
            lines.append(f"  seed drift: {k} {va!r} -> {vb!r}")
        shown = self.verdicts if verbose else self.regressions
        for v in shown:
            lines.append("  " + v.render())
        if self.clean and not verbose:
            lines.append(f"  {len(self.verdicts)} metrics within tolerance")
        return "\n".join(lines)


def sentinel_compare(baseline: RunManifest, fresh: RunManifest,
                     default_tol: float = DEFAULT_TOLERANCE,
                     tolerances: Optional[Dict[str, float]] = None
                     ) -> SentinelReport:
    """Check every baseline metric against the fresh run's value.

    A metric missing from the fresh run counts as regressed (the summary
    lost a field); metrics only the fresh run has are ignored (new fields
    are not regressions — re-baseline to start tracking them).  Config or
    seed drift is always a regression: a CI gate must not silently accept
    "the knobs changed, so the numbers did too".

    Raises ``ValueError`` on kind mismatch (engine vs cluster baselines
    are not comparable).
    """
    if baseline.kind != fresh.kind:
        raise ValueError(f"kind mismatch: baseline is {baseline.kind!r}, "
                         f"fresh is {fresh.kind!r} — not comparable")
    tolerances = tolerances or {}
    rep = SentinelReport(baseline.label or "baseline",
                         fresh.label or "fresh",
                         identical_digest=baseline.digest == fresh.digest)
    for k in sorted(set(baseline.config) | set(fresh.config)):
        va, vb = baseline.config.get(k), fresh.config.get(k)
        if va != vb:
            rep.config_changes[k] = (va, vb)
    for k in sorted(set(baseline.seeds) | set(fresh.seeds)):
        va, vb = baseline.seeds.get(k), fresh.seeds.get(k)
        if va != vb:
            rep.seed_changes[k] = (va, vb)
    for name in sorted(baseline.metrics):
        want = baseline.metrics[name]
        got = fresh.metrics.get(name)
        tol = tolerances.get(name, default_tol)
        if got is None:
            rep.verdicts.append(MetricVerdict(name, want, float("nan"),
                                              tol, ok=False))
            continue
        ok = abs(got - want) <= tol * max(abs(want), abs(got)) \
            or got == want
        rep.verdicts.append(MetricVerdict(name, want, got, tol, ok))
    return rep


# ----------------------------------------------------------------------
# BENCH_doctor.json trajectory
# ----------------------------------------------------------------------
def trajectory_entry(fresh: RunManifest, report: SentinelReport,
                     doctor_doc: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """One trajectory record: run identity + verdict + headline metrics
    (+ the doctor's ranked findings when a diagnosis rode along)."""
    entry: Dict[str, Any] = {
        "label": fresh.label, "kind": fresh.kind, "digest": fresh.digest,
        "recorded_unix": int(time.time()),
        "clean": report.clean,
        "regressions": len(report.regressions),
        "metrics": {k: fresh.metrics[k] for k in HEADLINE_METRICS
                    if k in fresh.metrics},
    }
    if doctor_doc is not None:
        entry["findings"] = [
            {"slug": f["slug"],
             "recoverable_seconds": f["recoverable_seconds"],
             "method": f["method"]}
            for f in doctor_doc.get("findings", [])]
    return entry


def append_trajectory(path: str, entry: Dict[str, Any]) -> int:
    """Append one entry to the trajectory file; returns the new length."""
    doc: Dict[str, Any] = {"schema": TRAJECTORY_SCHEMA, "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema", TRAJECTORY_SCHEMA) > TRAJECTORY_SCHEMA:
            raise ValueError(f"trajectory schema {doc.get('schema')} is "
                             f"newer than supported {TRAJECTORY_SCHEMA}")
        doc.setdefault("runs", [])
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return len(doc["runs"])
