"""CLI: diff manifests, diagnose runs, and gate CI on a baseline.

    PYTHONPATH=src python -m repro.obs diff a.json b.json
    PYTHONPATH=src python -m repro.obs doctor              # camping demo
    PYTHONPATH=src python -m repro.obs doctor clean --expect-clean
    PYTHONPATH=src python -m repro.obs doctor lenet        # jax capture
    PYTHONPATH=src python -m repro.obs sentinel baseline.json fresh.json

Exit codes (relied on by the CI smoke steps):

* 0 — clean (identical manifests / zero-or-expected findings / sentinel
  within tolerance);
* 3 — divergence (diff found changes; sentinel found a regression;
  ``--expect-top``/``--expect-clean`` mismatched);
* 2 — usage or load error (missing file, malformed manifest,
  engine-vs-cluster kind mismatch, unknown workload).
"""
from __future__ import annotations

import argparse
import json
import sys

#: built-in demo workloads `doctor` can run without a jax capture
DEMO_WORKLOADS = ("camping", "clean", "no-overlap")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Observability toolbox for repro run artifacts.")
    sub = p.add_subparsers(dest="command", required=True)

    d = sub.add_parser(
        "diff", help="compare two --manifest JSONs and attribute "
                     "which layer/metric/interval diverged")
    d.add_argument("a", help="baseline manifest JSON path")
    d.add_argument("b", help="candidate manifest JSON path")
    d.add_argument("--rel-tol", type=float, default=1e-9,
                   help="relative tolerance below which a metric delta "
                        "is noise (default 1e-9: deterministic sims "
                        "must match exactly)")
    d.add_argument("--top", type=int, default=12,
                   help="rows shown per section in the text report")
    d.add_argument("--json", action="store_true",
                   help="emit the structured diff document instead of text")

    doc = sub.add_parser(
        "doctor", help="diagnose a run: ranked findings with "
                       "counterfactual recoverable_seconds")
    doc.add_argument("workload", nargs="?", default="camping",
                     help="built-in demo (camping | clean | no-overlap) "
                          "or a registered architecture id to capture "
                          "(e.g. lenet; needs jax). Default: camping")
    doc.add_argument("--hw", default="tpu-v5e",
                     help="chip spec (tpu-v5e|tpu-v5p)")
    doc.add_argument("--seq-len", type=int, default=32,
                     help="capture seq len for architecture workloads")
    doc.add_argument("--batch", type=int, default=8,
                     help="capture global batch for architecture workloads")
    doc.add_argument("--lapse-intervals", type=int, default=32,
                     help="time-lapse grid the findings localize on")
    doc.add_argument("--json", metavar="PATH",
                     help="write the DoctorReport JSON here ('-' stdout)")
    doc.add_argument("--chrome-trace", metavar="PATH",
                     help="write a chrome trace with the doctor "
                          "annotation overlay here ('-' for stdout)")
    doc.add_argument("--expect-top", metavar="SLUG",
                     help="exit 3 unless the top-ranked finding is SLUG "
                          "(CI gate)")
    doc.add_argument("--expect-clean", action="store_true",
                     help="exit 3 unless there are zero findings (CI gate)")

    s = sub.add_parser(
        "sentinel", help="gate CI: compare a fresh manifest against a "
                         "committed baseline with per-metric tolerances")
    s.add_argument("baseline", help="committed baseline manifest JSON")
    s.add_argument("fresh", help="freshly produced manifest JSON")
    s.add_argument("--default-tol", type=float, default=None,
                   help="relative tolerance for metrics without a --tol "
                        "rule (default 1e-6)")
    s.add_argument("--tol", action="append", default=[], metavar="M=REL",
                   help="per-metric tolerance rule, repeatable "
                        "(e.g. --tol mfu=0.05 --tol total_seconds=0.01)")
    s.add_argument("--append", metavar="PATH",
                   help="append this run to the BENCH_doctor.json "
                        "trajectory at PATH")
    s.add_argument("--json", action="store_true",
                   help="emit the structured verdict instead of text")
    s.add_argument("--verbose", action="store_true",
                   help="list every checked metric, not just regressions")
    return p


def _write(path: str, payload: str) -> None:
    if path == "-":
        print(payload)
    else:
        with open(path, "w") as f:
            f.write(payload)
        print(f"wrote {path}", file=sys.stderr)


def _cmd_diff(args) -> int:
    from repro.obs.diff import diff_manifests
    from repro.obs.manifest import RunManifest
    try:
        a = RunManifest.load(args.a)
        b = RunManifest.load(args.b)
    except (OSError, ValueError, KeyError) as e:
        print(f"error loading manifest: {e}", file=sys.stderr)
        return 2
    d = diff_manifests(a, b, rel_tol=args.rel_tol)
    try:
        if args.json:
            print(json.dumps(d.to_doc(), indent=2))
        else:
            print(d.render(top=args.top))
    except BrokenPipeError:     # `... | head` closed stdout; not an error
        sys.stderr.close()      # suppress the interpreter's flush warning
    if d.kind_mismatch:
        return 2
    return 0 if d.empty else 3


def _cmd_doctor(args) -> int:
    from repro.core import CHIPS
    from repro.obs.doctor import diagnose_demo, diagnose_engine
    if args.hw not in CHIPS:
        print(f"unknown --hw {args.hw!r}; known: {sorted(CHIPS)}",
              file=sys.stderr)
        return 2
    hw = CHIPS[args.hw]

    if args.workload in DEMO_WORKLOADS:
        doc, report = diagnose_demo(args.workload, hw=hw)
    else:
        # a registered architecture: capture + simulate + diagnose (the
        # same pipeline `python -m repro.analysis <arch> --doctor` runs)
        from repro import config as C
        from repro.core import Simulator
        from repro.obs.timelapse import TimeLapse
        from repro.runtime.steps import train_bundle
        try:
            entry = C.get(args.workload)
        except KeyError as e:
            print(e.args[0], file=sys.stderr)
            print(f"(built-in demos: {', '.join(DEMO_WORKLOADS)})",
                  file=sys.stderr)
            return 2
        shape = C.ShapeConfig("doctor", seq_len=args.seq_len,
                              global_batch=args.batch, kind="train")
        rc = C.RunConfig(model=entry.smoke, shape=shape, mesh=C.SMOKE_MESH)
        sim = Simulator(hw=hw)
        print(f"capturing {args.workload} train step (seq={args.seq_len}, "
              f"batch={args.batch}, {args.hw}) ...", file=sys.stderr)
        cap = sim.capture_bundle(train_bundle(rc),
                                 name=f"{args.workload}_doctor")
        report = sim.performance(cap)
        lapse = TimeLapse.from_report(report,
                                      num_intervals=args.lapse_intervals,
                                      label=args.workload)
        doc = diagnose_engine(report, engine=sim.engine, module=cap.module,
                              lapse=lapse, label=args.workload)

    print(doc.table())
    if args.json:
        _write(args.json, doc.to_json(indent=2))
    if args.chrome_trace:
        from repro.obs.export import trace_json
        _write(args.chrome_trace, trace_json(doc.to_chrome_events()))

    if args.expect_clean and doc.findings:
        print(f"expected a clean bill, found "
              f"{[f.slug for f in doc.findings]}", file=sys.stderr)
        return 3
    if args.expect_top:
        top = doc.top.slug if doc.top else None
        if top != args.expect_top:
            print(f"expected top finding {args.expect_top!r}, got "
                  f"{top!r}", file=sys.stderr)
            return 3
    return 0


def _cmd_sentinel(args) -> int:
    from repro.obs.manifest import RunManifest
    from repro.obs.sentinel import (DEFAULT_TOLERANCE, append_trajectory,
                                    parse_tolerances, sentinel_compare,
                                    trajectory_entry)
    try:
        tols = parse_tolerances(args.tol)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    try:
        baseline = RunManifest.load(args.baseline)
        fresh = RunManifest.load(args.fresh)
        rep = sentinel_compare(
            baseline, fresh,
            default_tol=args.default_tol if args.default_tol is not None
            else DEFAULT_TOLERANCE,
            tolerances=tols)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(rep.to_doc(), indent=2))
    else:
        print(rep.render(verbose=args.verbose))
    if args.append:
        n = append_trajectory(args.append, trajectory_entry(fresh, rep))
        print(f"appended run #{n} to {args.append}", file=sys.stderr)
    return 0 if rep.clean else 3


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.command == "diff":
        return _cmd_diff(args)
    if args.command == "doctor":
        return _cmd_doctor(args)
    return _cmd_sentinel(args)


if __name__ == "__main__":
    sys.exit(main())
