"""CLI: compare two run manifests and attribute the regression.

    PYTHONPATH=src python -m repro.obs diff a.json b.json

Exit codes (relied on by the CI smoke step):

* 0 — manifests are indistinguishable (the same-seed self-diff case);
* 3 — the runs diverged (config / seed / metric / time-lapse changes
  found — the "a knob changed" case);
* 2 — usage or load error (missing file, malformed manifest,
  engine-vs-cluster kind mismatch).
"""
from __future__ import annotations

import argparse
import json
import sys


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Observability toolbox for repro run artifacts.")
    sub = p.add_subparsers(dest="command", required=True)
    d = sub.add_parser(
        "diff", help="compare two --manifest JSONs and attribute "
                     "which layer/metric/interval diverged")
    d.add_argument("a", help="baseline manifest JSON path")
    d.add_argument("b", help="candidate manifest JSON path")
    d.add_argument("--rel-tol", type=float, default=1e-9,
                   help="relative tolerance below which a metric delta "
                        "is noise (default 1e-9: deterministic sims "
                        "must match exactly)")
    d.add_argument("--top", type=int, default=12,
                   help="rows shown per section in the text report")
    d.add_argument("--json", action="store_true",
                   help="emit the structured diff document instead of text")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    from repro.obs.diff import diff_manifests
    from repro.obs.manifest import RunManifest

    try:
        a = RunManifest.load(args.a)
        b = RunManifest.load(args.b)
    except (OSError, ValueError, KeyError) as e:
        print(f"error loading manifest: {e}", file=sys.stderr)
        return 2

    d = diff_manifests(a, b, rel_tol=args.rel_tol)
    try:
        if args.json:
            print(json.dumps(d.to_doc(), indent=2))
        else:
            print(d.render(top=args.top))
    except BrokenPipeError:     # `... | head` closed stdout; not an error
        sys.stderr.close()      # suppress the interpreter's flush warning
    if d.kind_mismatch:
        return 2
    return 0 if d.empty else 3


if __name__ == "__main__":
    sys.exit(main())
