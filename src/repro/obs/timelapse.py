"""AerialVision-style time-lapse: fixed-interval time series of a run.

The paper's AerialVision tool (§IV) plots per-interval IPC and DRAM
efficiency over a kernel's lifetime because aggregate counters hide the
"many varying phases" inside one cuDNN call, and its partition-camping
finding (§V) is only visible as a *per-interval* DRAM-bank imbalance.
This module is that view for the TPU stack, derived entirely from
timelines the simulators already produce — no second simulation:

* :meth:`TimeLapse.from_report` — per-unit occupancy, per-HBM-channel
  busy time + channel-imbalance ("camping") index, and per-ICI-link
  utilization for one engine run;
* :meth:`TimeLapse.from_cluster` — per-device occupancy and waiting-job
  queue depth for one fleet run.

Conservation property (tested, the acceptance bar): summing any busy
quantity over all intervals reproduces the corresponding ``SimReport``
/ ``ClusterReport`` total within 1%, because each timeline entry is
smeared over its true span exactly as :mod:`repro.analysis.intervals`
does — the per-channel seconds reconstruct ``MemoryModel.account``
(``channel_bytes[c] / hbm_channel_bw * scale``) and per-link seconds
come from the entry's recorded ``link_seconds``.

Exporters: :meth:`to_json` / :meth:`to_csv` for notebooks,
:meth:`heat_strips` for terminals (the shared :data:`~repro.obs.export.
SHADES` ramp), :meth:`to_chrome_events` for composed trace files, and
:meth:`to_doc` / :meth:`from_doc` for embedding in run manifests.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.export import SHADES, counter_event, shade, thread_meta

#: engine functional units shown per interval (matches analysis.UNITS)
UNITS = ("mxu", "vpu", "hbm", "ici")

#: chrome-trace counter-track tids for time-lapse series (pid 0 —
#: simulated time — after the fleet queue/fabric tracks at 1000/1001)
_LAPSE_TID = 1100


@dataclass
class LapseInterval:
    """One fixed-width time bucket of a time-lapse series.

    ``busy_seconds`` keys are functional units for engine lapses and
    device ids for cluster lapses; the channel/link/camping fields are
    engine-only and stay empty on cluster lapses.
    """

    index: int
    t0: float
    t1: float
    busy_seconds: Dict[str, float] = field(default_factory=dict)
    #: per-HBM-channel transfer busy seconds inside this bucket
    channel_busy: List[float] = field(default_factory=list)
    #: per-ICI-link busy seconds ("ici:<src>-<dst>" keys) inside this bucket
    link_busy: Dict[str, float] = field(default_factory=dict)
    #: busy seconds contributed by camping-class ops (gather/scatter/...)
    camping_seconds: float = 0.0
    #: scale-weighted HLO ops (engine) or job-slice count (cluster) here
    ops_retired: float = 0.0
    #: mean waiting-job queue depth over this bucket (cluster lapses)
    queue_depth: float = 0.0

    @property
    def width(self) -> float:
        return self.t1 - self.t0

    def occupancy(self, key: str) -> float:
        """Busy fraction for one unit/device, clamped to [0, 1] for display
        (trip-count-scaled regions can exceed the bucket width)."""
        if self.width <= 0:
            return 0.0
        return min(self.busy_seconds.get(key, 0.0) / self.width, 1.0)

    @property
    def channel_imbalance(self) -> float:
        """Busiest-channel / mean busy seconds in this bucket — the
        per-interval partition-camping index (1.0 = balanced).  Camped
        intervals read well above the module-level CAMPED_THRESHOLD."""
        if not self.channel_busy:
            return 1.0
        mean = sum(self.channel_busy) / len(self.channel_busy)
        if mean <= 0:
            return 1.0
        return max(self.channel_busy) / mean

    def to_doc(self) -> Dict[str, Any]:
        return {"t0": self.t0, "t1": self.t1,
                "busy_seconds": dict(self.busy_seconds),
                "channel_busy": list(self.channel_busy),
                "link_busy": dict(self.link_busy),
                "channel_imbalance": self.channel_imbalance,
                "camping_seconds": self.camping_seconds,
                "ops_retired": self.ops_retired,
                "queue_depth": self.queue_depth}


#: per-interval channel-imbalance above this marks the bucket as camped —
#: hoisted to the shared pathology-threshold config so the doctor's camping
#: verdicts always agree with the "!" markers rendered here
from repro.obs.thresholds import CAMPED_THRESHOLD  # noqa: E402


@dataclass
class TimeLapse:
    """A fixed-interval time series over one run (engine or cluster)."""

    kind: str                       # "engine" | "cluster"
    label: str                      # workload / trace x policy name
    intervals: List[LapseInterval]
    #: the reference totals this lapse must reconcile against
    reference: Dict[str, float] = field(default_factory=dict)

    @property
    def end_time(self) -> float:
        return self.intervals[-1].t1 if self.intervals else 0.0

    @property
    def keys(self) -> List[str]:
        """Every busy-series key present (units or device ids), sorted —
        engine lapses keep the canonical UNITS order."""
        seen = set()
        for iv in self.intervals:
            seen.update(iv.busy_seconds)
        if self.kind == "engine":
            return [u for u in UNITS if u in seen] + \
                sorted(seen - set(UNITS))
        return sorted(seen)

    def camped_intervals(self) -> List[int]:
        """Indices whose channel-imbalance index exceeds the camping bar."""
        return [iv.index for iv in self.intervals
                if iv.channel_busy and sum(iv.channel_busy) > 0
                and iv.channel_imbalance > CAMPED_THRESHOLD]

    # -- conservation ---------------------------------------------------
    def totals(self) -> Dict[str, float]:
        """Sums over all intervals, keyed to match :attr:`reference`."""
        out: Dict[str, float] = {}
        for iv in self.intervals:
            for k, v in iv.busy_seconds.items():
                out[f"busy_{k}_seconds"] = out.get(f"busy_{k}_seconds",
                                                   0.0) + v
            for c, v in enumerate(iv.channel_busy):
                out[f"channel_{c}_seconds"] = out.get(
                    f"channel_{c}_seconds", 0.0) + v
            for l, v in iv.link_busy.items():
                out[f"link_{l}_seconds"] = out.get(f"link_{l}_seconds",
                                                   0.0) + v
        return out

    def reconcile(self) -> float:
        """Max relative error between interval sums and reference totals.

        The subsystem's acceptance bar: < 1% on full (non-windowed) runs.
        """
        got = self.totals()
        worst = 0.0
        for key, expect in self.reference.items():
            if expect <= 0:
                continue
            worst = max(worst, abs(got.get(key, 0.0) - expect) / expect)
        return worst

    # -- constructors ---------------------------------------------------
    @classmethod
    def from_report(cls, report, num_intervals: int = 64,
                    label: str = "") -> "TimeLapse":
        """Time-lapse of one engine :class:`~repro.core.engine.SimReport`.

        Smears each timeline entry over its wall-clock span exactly as
        :func:`repro.analysis.intervals.profile_intervals` does, but
        additionally splits the busy time by HBM channel (reconstructing
        ``MemoryModel.account``'s ``bytes/bw*scale``) and by ICI link
        (the entry's recorded ``link_seconds``).
        """
        from repro.memory.channels import is_camping_op
        if num_intervals <= 0:
            raise ValueError(
                f"num_intervals must be positive, got {num_intervals}")
        n_ch = len(report.channel_busy_seconds)
        ref = {f"busy_{u}_seconds": report.unit_seconds.get(u, 0.0)
               for u in UNITS}
        ref.update({f"channel_{c}_seconds": s
                    for c, s in enumerate(report.channel_busy_seconds)})
        ref.update({f"link_{l}_seconds": s
                    for l, s in report.link_busy_seconds.items()})
        if not report.timeline:
            return cls("engine", label, [], ref)
        end = max(e.start + e.duration * e.scale for e in report.timeline)
        end = max(end, report.total_seconds, 1e-12)
        width = end / num_intervals
        ivs = [LapseInterval(i, i * width, (i + 1) * width,
                             channel_busy=[0.0] * n_ch)
               for i in range(num_intervals)]
        bw = report.hw.hbm_channel_bw

        for e in report.timeline:
            span = e.duration * e.scale
            camping = is_camping_op(e.opcode, e.name)
            if span <= 0:
                bi = min(int(e.start / width), num_intervals - 1)
                ivs[bi].ops_retired += e.scale
                continue
            t0, t1 = e.start, e.start + span
            b0 = min(int(t0 / width), num_intervals - 1)
            b1 = min(int(t1 / width), num_intervals - 1)
            link_seconds = getattr(e, "link_seconds", None)
            for bi in range(b0, b1 + 1):
                iv = ivs[bi]
                frac = max(min(t1, iv.t1) - max(t0, iv.t0), 0.0) / span
                if frac <= 0 and not (b0 == b1):
                    continue
                if b0 == b1:
                    frac = 1.0   # guard FP loss: entry fits one bucket
                iv.busy_seconds[e.unit] = (iv.busy_seconds.get(e.unit, 0.0)
                                           + span * frac)
                iv.ops_retired += e.scale * frac
                if camping:
                    iv.camping_seconds += span * frac
                if e.channel_bytes and bw > 0:
                    for c, v in enumerate(e.channel_bytes):
                        iv.channel_busy[c] += v / bw * e.scale * frac
                if link_seconds:
                    for l, sec in link_seconds.items():
                        iv.link_busy[l] = (iv.link_busy.get(l, 0.0)
                                           + sec * e.scale * frac)
        return cls("engine", label, ivs, ref)

    @classmethod
    def from_cluster(cls, report, num_intervals: int = 64,
                     label: str = "") -> "TimeLapse":
        """Time-lapse of one fleet :class:`~repro.cluster.events.
        ClusterReport`: per-device occupancy + waiting-job queue depth."""
        from repro.cluster.export import _queue_depth_events
        if num_intervals <= 0:
            raise ValueError(
                f"num_intervals must be positive, got {num_intervals}")
        label = label or f"{report.trace_name} x {report.policy}"
        ref = {f"busy_{d}_seconds": s
               for d, s in report.per_device_busy.items()}
        if not report.slices or report.makespan_s <= 0:
            return cls("cluster", label, [], ref)
        end = max(report.makespan_s,
                  max(s.t1 for s in report.slices), 1e-12)
        width = end / num_intervals
        ivs = [LapseInterval(i, i * width, (i + 1) * width)
               for i in range(num_intervals)]

        for s in report.slices:
            # only "run" slices count toward per_device_busy; setup/ckpt/
            # restore kinds are accounted separately by time_accounting()
            if s.kind != "run":
                continue
            span = s.t1 - s.t0
            if span <= 0:
                continue
            b0 = min(int(s.t0 / width), num_intervals - 1)
            b1 = min(int(s.t1 / width), num_intervals - 1)
            for bi in range(b0, b1 + 1):
                iv = ivs[bi]
                frac = max(min(s.t1, iv.t1) - max(s.t0, iv.t0), 0.0) / span
                if frac <= 0 and not (b0 == b1):
                    continue
                if b0 == b1:
                    frac = 1.0
                iv.busy_seconds[s.device_id] = (
                    iv.busy_seconds.get(s.device_id, 0.0) + span * frac)
                iv.ops_retired += frac

        # queue depth: integrate the (+1/-1) waiting deltas per bucket
        deltas = _queue_depth_events(report)
        depth, di = 0, 0
        for iv in ivs:
            area = 0.0
            t = iv.t0
            while di < len(deltas) and deltas[di][0] < iv.t1:
                dt_ev = max(deltas[di][0], iv.t0)
                area += depth * (dt_ev - t)
                depth += deltas[di][1]
                t = dt_ev
                di += 1
            area += depth * (iv.t1 - t)
            iv.queue_depth = area / iv.width if iv.width > 0 else 0.0
        return cls("cluster", label, ivs, ref)

    # -- exporters ------------------------------------------------------
    def to_doc(self) -> Dict[str, Any]:
        """Plain-dict form for manifests (:meth:`from_doc` round-trips)."""
        return {"kind": self.kind, "label": self.label,
                "num_intervals": len(self.intervals),
                "end_time": self.end_time,
                "reconcile_max_rel_error": self.reconcile(),
                "camped_intervals": self.camped_intervals(),
                "reference": dict(self.reference),
                "intervals": [iv.to_doc() for iv in self.intervals]}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "TimeLapse":
        ivs = [LapseInterval(
            i, d["t0"], d["t1"],
            busy_seconds=dict(d.get("busy_seconds", {})),
            channel_busy=list(d.get("channel_busy", [])),
            link_busy=dict(d.get("link_busy", {})),
            camping_seconds=d.get("camping_seconds", 0.0),
            ops_retired=d.get("ops_retired", 0.0),
            queue_depth=d.get("queue_depth", 0.0))
            for i, d in enumerate(doc.get("intervals", []))]
        return cls(doc.get("kind", "engine"), doc.get("label", ""), ivs,
                   dict(doc.get("reference", {})))

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_doc(), indent=indent)

    def to_csv(self) -> str:
        """One row per interval; channel/link series as flat columns."""
        n_ch = max((len(iv.channel_busy) for iv in self.intervals),
                   default=0)
        links = sorted({l for iv in self.intervals for l in iv.link_busy})
        keys = self.keys
        cols = (["index", "t0", "t1"]
                + [f"busy_{k}_s" for k in keys]
                + [f"channel_{c}_s" for c in range(n_ch)]
                + ["channel_imbalance", "camping_s"]
                + [f"{l}_s" for l in links]
                + ["ops_retired", "queue_depth"])
        lines = [",".join(cols)]
        for iv in self.intervals:
            row = ([str(iv.index), f"{iv.t0:.9g}", f"{iv.t1:.9g}"]
                   + [f"{iv.busy_seconds.get(k, 0.0):.9g}" for k in keys]
                   + [f"{iv.channel_busy[c]:.9g}" if c < len(iv.channel_busy)
                      else "0" for c in range(n_ch)]
                   + [f"{iv.channel_imbalance:.4g}",
                      f"{iv.camping_seconds:.9g}"]
                   + [f"{iv.link_busy.get(l, 0.0):.9g}" for l in links]
                   + [f"{iv.ops_retired:.9g}", f"{iv.queue_depth:.4g}"])
            lines.append(",".join(row))
        return "\n".join(lines)

    def heat_strips(self, width: int = 72) -> str:
        """Terminal heat-strip rendering, one shaded row per series.

        Engine lapses add a ``camp`` row (per-interval channel-imbalance,
        ``!`` above the camped threshold) — the terminal analogue of the
        paper's per-interval DRAM-efficiency dip under partition camping.
        """
        if not self.intervals:
            return "(empty time-lapse)"
        n = len(self.intervals)
        stride = max(-(-n // width), 1)
        cols = list(range(0, n, stride))

        def mean_over(fn) -> List[float]:
            out = []
            for i in cols:
                window = self.intervals[i:i + stride]
                out.append(sum(fn(iv) for iv in window) / len(window))
            return out

        pad = max((len(k) for k in self.keys), default=4)
        pad = max(pad, 5)
        lines = []
        for key in self.keys:
            vals = mean_over(lambda iv, k=key: iv.occupancy(k))
            lines.append(f"{key:>{pad}s} |"
                         f"{''.join(shade(v) for v in vals)}|")
        if any(iv.channel_busy for iv in self.intervals):
            camp = mean_over(lambda iv: iv.channel_imbalance)
            cells = "".join("!" if v > CAMPED_THRESHOLD
                            else shade((v - 1.0) / max(CAMPED_THRESHOLD, 1))
                            for v in camp)
            lines.append(f"{'camp':>{pad}s} |{cells}|")
        if self.kind == "cluster":
            q = mean_over(lambda iv: iv.queue_depth)
            strip = "".join("*" if v > 9 else (str(int(v)) if v >= 1
                                               else ".") for v in q)
            lines.append(f"{'queue':>{pad}s} |{strip}|")
        lines.append(f"{'':>{pad}s}  0s {'-' * max(len(cols) - 14, 4)} "
                     f"{self.end_time:.3e}s")
        if any(iv.channel_busy for iv in self.intervals):
            lines.append(f"{'':>{pad}s}  camp row: channel-imbalance "
                         f"(!: camped, index > {CAMPED_THRESHOLD})")
        return "\n".join(lines)

    def to_chrome_events(self, pid: int = 0) -> List[dict]:
        """Counter tracks (``ph: C``) composing with op/fleet/span tracks."""
        if not self.intervals:
            return []
        events = [thread_meta("timelapse", tid=_LAPSE_TID, pid=pid)]
        for iv in self.intervals:
            events.append(counter_event(
                "lapse_occupancy", "timelapse", iv.t0,
                {k: round(iv.occupancy(k), 4) for k in iv.busy_seconds},
                pid=pid, tid=_LAPSE_TID))
            if iv.channel_busy:
                events.append(counter_event(
                    "lapse_channel_imbalance", "timelapse", iv.t0,
                    {"index": round(iv.channel_imbalance, 4)},
                    pid=pid, tid=_LAPSE_TID))
            if self.kind == "cluster":
                events.append(counter_event(
                    "lapse_queue_depth", "timelapse", iv.t0,
                    {"jobs_waiting": round(iv.queue_depth, 3)},
                    pid=pid, tid=_LAPSE_TID))
        return events
