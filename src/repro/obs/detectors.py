"""Pathology detectors: telemetry in, named structured findings out.

Each detector is one rule over a run's existing telemetry — the
``SimReport``/``ClusterReport`` summaries, the timeline, and (when given)
the PR 8 time-lapse intervals — and emits a :class:`Finding` naming the
pathology, its evidence metrics, the affected ops/devices/links and the
time-lapse interval span where it concentrates.  Detection is cheap and
purely observational; *pricing* what a fix would buy is the what-if
engine's job (:mod:`repro.obs.whatif`), which the doctor runs per finding
to fill ``recoverable_seconds``.

All cutoffs come from the shared :class:`~repro.obs.thresholds.Thresholds`
config, so a doctor verdict can never disagree with the timelapse heat
strips or the links table.

Registries are plain lists of callables — register a custom rule with the
:func:`engine_detector` / :func:`cluster_detector` decorators.  Engine
detectors take ``(report, summary, lapse, thresholds)``; cluster detectors
take ``(report, summary, lapse, thresholds, context)`` where ``context``
optionally carries the run's :class:`~repro.faults.CheckpointModel` and
MTBF (the CLI passes them) for the Young–Daly rule.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.thresholds import DEFAULT_THRESHOLDS, Thresholds

#: what-if slug each engine finding is priced with (identity for engine
#: pathologies; cluster findings are analytic and carry their own price)
ENGINE_DETECTORS: List[Callable] = []
CLUSTER_DETECTORS: List[Callable] = []


@dataclass
class Finding:
    """One named pathology diagnosed on a run."""

    slug: str                     # stable id, e.g. "hbm-channel-camping"
    title: str                    # human-readable one-liner
    evidence: Dict[str, float] = field(default_factory=dict)
    #: affected ops / devices / links, hottest first
    affected: List[str] = field(default_factory=list)
    #: (first, last) time-lapse interval index where it concentrates
    interval_span: Optional[Tuple[int, int]] = None
    #: wall-time span of ``interval_span`` in simulated seconds
    span_seconds: Optional[Tuple[float, float]] = None
    #: what fixing ONLY this would buy (filled by the doctor's what-if
    #: pass for engine findings; analytic for cluster findings)
    recoverable_seconds: float = 0.0
    #: how recoverable_seconds was priced: "tape-replay" | "engine-knob"
    #: | "analytic" | "unpriced"
    method: str = "unpriced"
    detail: str = ""

    def to_doc(self) -> Dict[str, Any]:
        return {"slug": self.slug, "title": self.title,
                "evidence": dict(self.evidence),
                "affected": list(self.affected),
                "interval_span": list(self.interval_span)
                if self.interval_span else None,
                "span_seconds": list(self.span_seconds)
                if self.span_seconds else None,
                "recoverable_seconds": self.recoverable_seconds,
                "method": self.method,
                "detail": self.detail}


def engine_detector(fn: Callable) -> Callable:
    ENGINE_DETECTORS.append(fn)
    return fn


def cluster_detector(fn: Callable) -> Callable:
    CLUSTER_DETECTORS.append(fn)
    return fn


def _lapse_span(lapse, indices) -> Tuple[Optional[Tuple[int, int]],
                                         Optional[Tuple[float, float]]]:
    """(interval_span, span_seconds) for a set of flagged interval
    indices (None, None when nothing is flagged or no lapse given)."""
    if lapse is None or not indices:
        return None, None
    lo, hi = min(indices), max(indices)
    return (lo, hi), (lapse.intervals[lo].t0, lapse.intervals[hi].t1)


# ----------------------------------------------------------------------
# engine detectors (report, summary, lapse, thresholds) -> Finding | None
# ----------------------------------------------------------------------
@engine_detector
def detect_channel_camping(report, s, lapse,
                           th: Thresholds) -> Optional[Finding]:
    """HBM channel camping: camping-class ops concentrate their traffic on
    an address-derived channel subset (paper §V, Figs. 22-25)."""
    from repro.memory.channels import is_camping_op
    camped = lapse.camped_intervals() if lapse is not None else []
    imb = s.get("channel_imbalance", 0.0)
    if not camped and imb <= th.channel_camping_imbalance:
        return None
    campers: Dict[str, float] = {}
    camp_busy = 0.0
    for e in report.timeline:
        if is_camping_op(e.opcode, e.name):
            sec = e.duration * e.scale
            campers[e.name] = campers.get(e.name, 0.0) + sec
            camp_busy += sec
    if camp_busy <= 0:
        return None
    span, span_s = _lapse_span(lapse, camped)
    top = sorted(campers.items(), key=lambda kv: -kv[1])[:4]
    return Finding(
        "hbm-channel-camping",
        "HBM channel camping: camping-class ops gate on a channel subset",
        evidence={"channel_imbalance": imb,
                  "camping_busy_seconds": camp_busy,
                  "camped_intervals": float(len(camped))},
        affected=[n for n, _ in top],
        interval_span=span, span_seconds=span_s)


@engine_detector
def detect_link_imbalance(report, s, lapse,
                          th: Thresholds) -> Optional[Finding]:
    """Fabric link camping: one axis' links carry most of the collective
    traffic while the rest of the fabric idles."""
    from repro.analysis.links import link_traffic
    lr = link_traffic(report)
    if lr.num_links < 2 or lr.imbalance <= th.link_camping_imbalance:
        return None
    return Finding(
        "link-imbalance",
        "fabric link imbalance: a minority of ICI links gates the "
        "collectives",
        evidence={"link_imbalance": lr.imbalance,
                  "hot_link_bytes": lr.link_bytes.get(lr.hot_link, 0.0),
                  "total_link_bytes": lr.total_bytes},
        affected=[lr.hot_link] + [n for n, _ in lr.hot_contributors[:3]])


@engine_detector
def detect_exposed_comm(report, s, lapse,
                        th: Thresholds) -> Optional[Finding]:
    """Exposed communication: collective seconds the schedule failed to
    hide behind compute."""
    total = s.get("total_seconds", 0.0)
    exposed = s.get("exposed_ici_seconds", 0.0)
    if total <= 0 or exposed / total <= th.exposed_comm_fraction:
        return None
    hot = sorted((e for e in report.timeline if e.unit == "ici"),
                 key=lambda e: -getattr(e, "exposed_s", 0.0))[:4]
    return Finding(
        "exposed-communication",
        "exposed communication: collectives sit on the critical path "
        "instead of overlapping compute",
        evidence={"exposed_ici_seconds": exposed,
                  "exposed_fraction": exposed / total,
                  "ici_seconds": s.get("ici_seconds", 0.0)},
        affected=[e.name for e in hot])


@engine_detector
def detect_vmem_spill(report, s, lapse,
                      th: Thresholds) -> Optional[Finding]:
    """VMEM spill: working sets over VMEM capacity spill extra HBM
    traffic."""
    frac = s.get("spill_fraction", 0.0)
    if frac <= th.spill_fraction:
        return None
    spillers: Dict[str, float] = {}
    for e in report.timeline:
        sp = getattr(e, "spill_bytes", 0)
        if sp:
            spillers[e.name] = spillers.get(e.name, 0.0) + sp * e.scale
    top = sorted(spillers.items(), key=lambda kv: -kv[1])[:4]
    return Finding(
        "vmem-spill",
        "VMEM spill: over-capacity working sets stream extra HBM traffic",
        evidence={"spill_bytes": s.get("spill_bytes", 0.0),
                  "spill_fraction": frac},
        affected=[n for n, _ in top])


@engine_detector
def detect_launch_overhead(report, s, lapse,
                           th: Thresholds) -> Optional[Finding]:
    """Launch-overhead domination: fixed per-op issue cost outweighs the
    useful work (tiny-op workloads — the lenet smoke capture's verdict)."""
    total = s.get("total_seconds", 0.0)
    ovh = s.get("launch_overhead_seconds", 0.0)
    if total <= 0 or ovh / total <= th.launch_overhead_fraction:
        return None
    return Finding(
        "launch-overhead",
        "launch-overhead domination: per-op issue cost outweighs the "
        "useful work",
        evidence={"launch_overhead_seconds": ovh,
                  "overhead_fraction": ovh / total,
                  "timeline_ops": float(len(report.timeline))})


# ----------------------------------------------------------------------
# cluster detectors (report, summary, lapse, th, context) -> Finding|None
# ----------------------------------------------------------------------
@cluster_detector
def detect_hol_blocking(report, s, lapse, th: Thresholds,
                        context) -> Optional[Finding]:
    """Head-of-line blocking: the queue head couldn't fit while later
    jobs could have run."""
    n_jobs = max(len(report.jobs), 1)
    blocked = list(report.hol_blocked_jobs)
    if len(blocked) / n_jobs <= th.hol_blocked_fraction:
        return None
    mean_delay = s.get("mean_queue_delay_s", 0.0)
    return Finding(
        "cluster-hol-blocking",
        "head-of-line blocking: queue-head jobs stall the backlog",
        evidence={"hol_events": float(report.hol_events),
                  "hol_blocked_jobs": float(len(blocked)),
                  "blocked_fraction": len(blocked) / n_jobs,
                  "mean_queue_delay_s": mean_delay},
        affected=blocked[:6],
        recoverable_seconds=mean_delay * len(blocked),
        method="analytic",
        detail="estimate: blocked jobs x mean queue delay (a "
               "size-aware policy bypasses the blocker)")


@cluster_detector
def detect_gang_stragglers(report, s, lapse, th: Thresholds,
                           context) -> Optional[Finding]:
    """Gang stragglers: one member device of a lockstep gang stays busier
    than its peers, dilating every step for the whole gang."""
    gangs: Dict[tuple, Dict[str, float]] = {}
    for sl in report.slices:
        if sl.kind != "run" or not sl.group or len(sl.group) < 2:
            continue
        per_dev = gangs.setdefault(tuple(sl.group), {})
        per_dev[sl.device_id] = per_dev.get(sl.device_id, 0.0) \
            + (sl.t1 - sl.t0)
    worst_dil, recoverable, laggards = 0.0, 0.0, []
    for group, per_dev in gangs.items():
        if len(per_dev) < 2:
            continue
        busy = list(per_dev.values())
        mean = sum(busy) / len(busy)
        if mean <= 0:
            continue
        peak = max(busy)
        dil = peak / mean
        if dil > th.straggler_dilation:
            recoverable += peak - mean
            laggards.append(max(per_dev, key=per_dev.get))
            worst_dil = max(worst_dil, dil)
    if not laggards:
        return None
    return Finding(
        "gang-stragglers",
        "gang stragglers: slowest members dilate lockstep gangs",
        evidence={"worst_dilation": worst_dil,
                  "straggling_gangs": float(len(laggards))},
        affected=sorted(set(laggards))[:6],
        recoverable_seconds=recoverable,
        method="analytic",
        detail="estimate: per-gang (peak - mean) member busy seconds")


@cluster_detector
def detect_checkpoint_interval(report, s, lapse, th: Thresholds,
                               context) -> Optional[Finding]:
    """Checkpoint cadence vs the Young–Daly optimum sqrt(2wM): too-frequent
    saves waste writes, too-rare saves waste lost work on failure."""
    from repro.faults.pricing import daly_interval
    ckpt = (context or {}).get("checkpoint")
    mtbf = (context or {}).get("mtbf_s")
    if ckpt is None or not mtbf or not math.isfinite(mtbf):
        return None
    tau = getattr(ckpt, "interval_s", 0.0)
    busy = report.fleet_busy_seconds
    if tau <= 0 or busy <= 0 or report.checkpoint_seconds <= 0:
        return None
    # effective mean write cost from the run itself: total write seconds
    # over the number of cadence cycles actually completed
    w = report.checkpoint_seconds * tau / busy
    tau_opt = daly_interval(w, mtbf)
    if not math.isfinite(tau_opt) or tau_opt <= 0:
        return None
    rel_err = abs(tau - tau_opt) / tau_opt
    if rel_err <= th.checkpoint_interval_rel_error:
        return None
    # first-order overhead fraction f(tau) = w/tau + tau/(2M)
    f_cur = w / tau + tau / (2.0 * mtbf)
    f_opt = w / tau_opt + tau_opt / (2.0 * mtbf)
    recoverable = max((f_cur - f_opt) * busy, 0.0)
    return Finding(
        "checkpoint-interval",
        "checkpoint cadence off the Young-Daly optimum",
        evidence={"interval_s": tau, "optimal_interval_s": tau_opt,
                  "rel_error": rel_err, "write_cost_s": w,
                  "mtbf_s": float(mtbf),
                  "checkpoint_seconds": report.checkpoint_seconds,
                  "lost_work_seconds": report.lost_work_seconds},
        recoverable_seconds=recoverable,
        method="analytic",
        detail=f"first-order overhead model w/tau + tau/2M; "
               f"move interval toward {tau_opt:.1f}s")


@cluster_detector
def detect_cache_miss_storm(report, s, lapse, th: Thresholds,
                            context) -> Optional[Finding]:
    """SimulationCache miss storm: per-job pricing keeps re-simulating
    instead of hitting the (module, hw, knobs) cache — a wall-clock
    pathology of the simulator itself, not of the simulated fleet."""
    hits, misses = report.cache_hits, report.cache_misses
    lookups = hits + misses
    if lookups < 16:
        return None
    rate = hits / lookups
    if rate >= th.cache_hit_rate_floor:
        return None
    price_wall = report.stage_seconds.get("price", 0.0)
    return Finding(
        "cache-miss-storm",
        "SimulationCache miss storm: cost pricing keeps re-simulating",
        evidence={"cache_hits": float(hits), "cache_misses": float(misses),
                  "hit_rate": rate},
        recoverable_seconds=price_wall * (1.0 - rate),
        method="analytic",
        detail="recoverable is simulator WALL-CLOCK pricing time (0 when "
               "the run was not stage-profiled), not simulated fleet time")


@cluster_detector
def detect_accounting_residual(report, s, lapse, th: Thresholds,
                               context) -> Optional[Finding]:
    """Conservation-law drift: Little's law / busy-time / utilization
    identities (``repro.validate``) disagree with the report.  Unlike
    every other cluster finding this is a verdict on the SIMULATOR, not
    the simulated fleet — the identities are exact, so any residual
    above float noise means the tape and the records tell different
    stories about the same run."""
    try:
        from repro.validate.queueing import conservation_checks
    except ImportError:                               # pragma: no cover
        return None
    bad = [c for c in conservation_checks(
        report, tol=th.conservation_residual) if not c.ok]
    if not bad:
        return None
    worst = max(bad, key=lambda c: c.residual)
    return Finding(
        "accounting-residual",
        f"{len(bad)} conservation identities violated "
        f"(worst {worst.name}: residual {worst.residual * 100:.3g}%)",
        evidence={c.name.replace("-", "_"): c.residual for c in bad},
        affected=[c.name for c in bad],
        method="analytic",
        detail="accounting drift is a simulator bug, not a workload "
               "effect — rerun with --validate for the full check table")


def run_engine_detectors(report, summary, lapse=None,
                         thresholds: Thresholds = DEFAULT_THRESHOLDS
                         ) -> List[Finding]:
    out = []
    for det in ENGINE_DETECTORS:
        f = det(report, summary, lapse, thresholds)
        if f is not None:
            out.append(f)
    return out


def run_cluster_detectors(report, summary, lapse=None,
                          thresholds: Thresholds = DEFAULT_THRESHOLDS,
                          context: Optional[Dict[str, Any]] = None
                          ) -> List[Finding]:
    out = []
    for det in CLUSTER_DETECTORS:
        f = det(report, summary, lapse, thresholds, context)
        if f is not None:
            out.append(f)
    return out
