"""Counterfactual repricing: what would the run cost with ONE pathology fixed?

The doctor's currency is ``recoverable_seconds`` — the makespan delta
between the run as simulated and the same run with a single pathology
idealized (evenly interleaved HBM traffic, a perfectly balanced fabric,
zero launch overhead, free communication, no VMEM spill).  Since PR 7 the
batched scheduler records every pricing input onto a
:class:`~repro.core.fastsched.ModuleTape`, so the counterfactual is cheap:
patch the affected EXEC steps' prices (:func:`~repro.core.fastsched.
patched_tape`) and :func:`~repro.core.fastsched.replay` the tape — no
re-capture, no re-walk, no allocator work.  When no tape applies (legacy
scheduler, or no engine/module at hand) each what-if falls back to a full
``Engine.simulate`` with the equivalent knob override, labeled as such in
``WhatIf.method`` because some knob fallbacks are coarser than the patch
(e.g. ``memory_model=False`` removes spill *and* camping at once).

The patchers mirror ``MemoryModel.time_op`` / ``op_time`` arithmetic
exactly, so e.g. the camping counterfactual equals an actual re-simulation
of the same program with contiguous (evenly striped) layouts — the
acceptance bar ``tests/test_doctor.py`` holds it to.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core import fastsched
from repro.core.fastsched import EXEC
from repro.core.timing import OpTime, op_time

#: what-if slugs priceable on an engine run (cluster findings are analytic)
ENGINE_WHATIFS = ("hbm-channel-camping", "vmem-spill", "launch-overhead",
                  "exposed-communication", "link-imbalance")


@dataclass
class WhatIf:
    """One counterfactual verdict: the run with a single pathology fixed."""

    slug: str
    baseline_seconds: float
    ideal_seconds: float
    #: "tape-replay" (patched-price replay, exact) or "engine-knob"
    #: (full re-simulation under a knob override, possibly coarser)
    method: str
    detail: str = ""

    @property
    def recoverable_seconds(self) -> float:
        """Seconds the fix would buy (clamped: an idealization that cannot
        help reads 0.0, never negative)."""
        return max(self.baseline_seconds - self.ideal_seconds, 0.0)


# ----------------------------------------------------------------------
# tape price patchers — each mirrors the pricing layer it idealizes
# ----------------------------------------------------------------------
def _unpack(st):
    (_k, out, deps, idx, node_id, ot, scale, chans, links, cbytes, spill,
     comp_name, op) = st
    return (out, deps, idx, node_id, ot, scale, chans, links, cbytes,
            spill, comp_name, op)


def _camping_fn(mod, hw) -> Callable:
    """Even-interleave counterfactual: every op's HBM traffic (spill
    included — it already stripes evenly) spread uniformly over all
    channels, then re-timed exactly as ``MemoryModel.time_op`` would."""
    n_ch = hw.hbm_channels
    ch_bw = hw.hbm_channel_bw

    def fn(st):
        (out, deps, idx, node_id, ot, scale, _chans, links, cbytes,
         spill, comp_name, op) = _unpack(st)
        if not cbytes or ot.unit == "ici":
            return st
        total = sum(cbytes)
        if total <= 0 or n_ch <= 0 or ch_bw <= 0:
            return st
        flat = op_time(mod, mod.computations[comp_name], op, hw)
        core = flat.seconds - flat.overhead_s
        t_hbm = (total / n_ch) / ch_bw
        unit, seconds = flat.unit, flat.seconds
        if t_hbm > core:
            unit, seconds = "hbm", t_hbm + flat.overhead_s
        elif flat.unit == "hbm":
            seconds = max(t_hbm, core) + flat.overhead_s
        ot2 = OpTime(seconds, unit, ot.flops, ot.hbm_bytes, ot.ici_bytes,
                     detail=ot.detail, overhead_s=ot.overhead_s)
        vec = [total / n_ch] * n_ch
        chans2 = tuple(range(n_ch)) if unit == "hbm" else None
        return (EXEC, out, deps, idx, node_id, ot2, scale, chans2,
                links, vec, spill, comp_name, op)
    return fn


def _spill_fn(mod, hw) -> Callable:
    """No-spill counterfactual: subtract the (evenly striped) spill bytes
    from each op's channel vector and re-time; camping distribution of the
    payload traffic is kept."""
    n_ch = hw.hbm_channels
    ch_bw = hw.hbm_channel_bw

    def fn(st):
        (out, deps, idx, node_id, ot, scale, chans, links, cbytes,
         spill, comp_name, op) = _unpack(st)
        if not cbytes or spill <= 0 or ot.unit == "ici" or ch_bw <= 0:
            return st
        sp_each = spill / max(n_ch, 1)
        vec = [max(v - sp_each, 0.0) for v in cbytes]
        flat = op_time(mod, mod.computations[comp_name], op, hw)
        core = flat.seconds - flat.overhead_s
        t_hbm = max(vec) / ch_bw if vec else 0.0
        unit, seconds = flat.unit, flat.seconds
        if t_hbm > core:
            unit, seconds = "hbm", t_hbm + flat.overhead_s
        elif flat.unit == "hbm":
            seconds = max(t_hbm, core) + flat.overhead_s
        ot2 = OpTime(seconds, unit, ot.flops, flat.hbm_bytes, ot.ici_bytes,
                     detail=ot.detail, overhead_s=ot.overhead_s)
        chans2 = tuple(c for c, v in enumerate(vec) if v > 0) \
            if unit == "hbm" else None
        return (EXEC, out, deps, idx, node_id, ot2, scale, chans2,
                links, vec, 0, comp_name, op)
    return fn


def _overhead_fn() -> Callable:
    """Zero-launch-overhead counterfactual: strip the issue cost out of
    every step (equals a re-simulation with ``op_launch_overhead_s=0`` —
    the overhead is a pure additive term in every pricing path)."""
    def fn(st):
        ot = st[5]
        if ot.overhead_s <= 0:
            return st
        # direct construction: dataclasses.replace costs ~10x as much and
        # this runs once per EXEC step per counterfactual
        ot2 = OpTime(max(ot.seconds - ot.overhead_s, 0.0), ot.unit,
                     ot.flops, ot.hbm_bytes, ot.ici_bytes,
                     detail=ot.detail, overhead_s=0.0,
                     link_seconds=ot.link_seconds,
                     link_bytes=ot.link_bytes)
        return st[:5] + (ot2,) + st[6:]
    return fn


def _comm_free_fn() -> Callable:
    """Perfect-overlap counterfactual: collectives cost only their issue
    overhead, so the makespan delta is exactly the communication time the
    schedule failed to hide."""
    def fn(st):
        (out, deps, idx, node_id, ot, scale, chans, _links, cbytes,
         spill, comp_name, op) = _unpack(st)
        if ot.unit != "ici":
            return st
        ot2 = OpTime(ot.overhead_s, ot.unit, ot.flops, ot.hbm_bytes,
                     ot.ici_bytes, detail=ot.detail,
                     overhead_s=ot.overhead_s)
        return (EXEC, out, deps, idx, node_id, ot2, scale, chans,
                None, cbytes, spill, comp_name, op)
    return fn


def _link_balance_fn(all_links: List[str]) -> Callable:
    """Balanced-fabric counterfactual: each collective's total link busy
    time spread evenly over every link the run touched, transfer time =
    the (now uniform) per-link share.  Conservative: links the program
    never used stay out of the denominator."""
    links2 = sorted(all_links)
    n = max(len(links2), 1)

    def fn(st):
        (out, deps, idx, node_id, ot, scale, chans, _links, cbytes,
         spill, comp_name, op) = _unpack(st)
        if ot.unit != "ici" or not ot.link_seconds:
            return st
        busy = sum(ot.link_seconds.values())
        share = busy / n
        transfer = max(ot.seconds - ot.overhead_s, 0.0)
        seconds = min(ot.seconds, share + ot.overhead_s) \
            if transfer > 0 else ot.seconds
        ls2 = {l: share for l in links2}
        ot2 = OpTime(seconds, ot.unit, ot.flops, ot.hbm_bytes,
                     ot.ici_bytes, detail=ot.detail,
                     overhead_s=ot.overhead_s, link_seconds=ls2)
        return (EXEC, out, deps, idx, node_id, ot2, scale, chans,
                list(links2), cbytes, spill, comp_name, op)
    return fn


# ----------------------------------------------------------------------
# knob-override fallbacks (no tape: legacy scheduler / missing engine)
# ----------------------------------------------------------------------
def _knob_engine(slug: str, engine, hw):
    """A fresh Engine with the one knob idealizing ``slug`` overridden."""
    from repro.core.engine import Engine
    kw = dict(
        hw=hw,
        overlap_collectives=engine.overlap if engine else True,
        num_compute_streams=engine.num_compute_streams if engine else 1,
        memory_model=engine.memory_model if engine else True,
        topology_model=engine.topology_model if engine else True,
        scheduler="batched")
    if slug in ("hbm-channel-camping", "vmem-spill"):
        kw["memory_model"] = False
    elif slug == "launch-overhead":
        kw["hw"] = dataclasses.replace(hw, op_launch_overhead_s=0.0)
    elif slug == "exposed-communication":
        kw["hw"] = dataclasses.replace(hw, ici_link_bw=1e30,
                                       ici_latency_s=0.0)
    elif slug == "link-imbalance":
        kw["topology_model"] = False
    else:
        raise KeyError(f"unknown engine what-if {slug!r} "
                       f"(expected one of {ENGINE_WHATIFS})")
    return Engine(**kw)


def whatif_engine(slug: str, report, engine=None, module=None
                  ) -> Optional[WhatIf]:
    """Price one pathology's counterfactual for an engine run.

    Prefers the tape tier (patch + replay); falls back to a knob-override
    ``Engine.simulate`` when no tape applies.  Returns ``None`` when
    neither is possible (no module to re-simulate).
    """
    if slug not in ENGINE_WHATIFS:
        raise KeyError(f"unknown engine what-if {slug!r} "
                       f"(expected one of {ENGINE_WHATIFS})")
    baseline = report.total_seconds
    tape = None
    if engine is not None and module is not None:
        tape = engine.tape_for(module)
    if tape is not None:
        hw = engine.hw
        if slug == "hbm-channel-camping":
            fn = _camping_fn(module, hw)
        elif slug == "vmem-spill":
            fn = _spill_fn(module, hw)
        elif slug == "launch-overhead":
            fn = _overhead_fn()
        elif slug == "exposed-communication":
            fn = _comm_free_fn()
        else:
            fn = _link_balance_fn(sorted(report.link_busy_seconds))
        from repro.obs.metrics import REGISTRY
        from repro.obs.trace import TRACER
        with TRACER.span("whatif.replay", pathology=slug):
            ideal = fastsched.replay(fastsched.patched_tape(tape, fn),
                                     engine, None, totals_only=True)
        REGISTRY.counter("whatif_tape_replays_total").inc()
        return WhatIf(slug, baseline, ideal.total_seconds, "tape-replay")
    if module is None:
        return None
    from repro.obs.metrics import REGISTRY
    from repro.obs.trace import TRACER
    knob = _knob_engine(slug, engine, engine.hw if engine else report.hw)
    with TRACER.span("whatif.knob_simulate", pathology=slug):
        ideal = knob.simulate(module)
    REGISTRY.counter("whatif_knob_fallbacks_total").inc()
    return WhatIf(slug, baseline, ideal.total_seconds, "engine-knob",
                  detail="full re-simulation under a knob override; "
                         "coarser than the tape patch")


def whatif_all(report, engine=None, module=None) -> Dict[str, WhatIf]:
    """Every engine counterfactual that can be priced for this run."""
    out: Dict[str, WhatIf] = {}
    for slug in ENGINE_WHATIFS:
        wi = whatif_engine(slug, report, engine=engine, module=module)
        if wi is not None:
            out[slug] = wi
    return out
