"""repro.obs.doctor — automated diagnosis with counterfactual pricing.

The paper's observability loop ends with a human squinting at AerialVision
plots to *name* the pathology (partition camping, §V).  The doctor closes
that loop mechanically: run every registered detector over a report
(:mod:`repro.obs.detectors`), price each finding's counterfactual through
the tape-replay what-if engine (:mod:`repro.obs.whatif`), and rank the
findings by ``recoverable_seconds`` — the seconds a fix would actually buy
on the simulated clock.  Exports: ASCII table, JSON doc, and chrome-trace
annotation overlays that compose with the PR 8 exporters
(``trace_json(op_events, lapse_events, doctor_events)``).

Entry points: :func:`diagnose_engine` / :func:`diagnose_cluster` (library),
``python -m repro.obs doctor`` (CLI, incl. built-in pathological demo
workloads), and ``--doctor`` on the analysis and cluster CLIs.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.detectors import (Finding, run_cluster_detectors,
                                 run_engine_detectors)
from repro.obs.export import duration_event, instant_event, thread_meta
from repro.obs.thresholds import DEFAULT_THRESHOLDS, Thresholds
from repro.obs.whatif import ENGINE_WHATIFS, whatif_engine

#: chrome-trace track for doctor annotations (pid 0 — simulated time —
#: after the time-lapse counter tracks at 1100)
_DOCTOR_TID = 1200


@dataclass
class DoctorReport:
    """Ranked findings for one run, with counterfactual prices."""

    kind: str                       # "engine" | "cluster"
    label: str
    baseline_seconds: float         # makespan the recoveries are against
    findings: List[Finding] = field(default_factory=list)

    @property
    def top(self) -> Optional[Finding]:
        return self.findings[0] if self.findings else None

    @property
    def recoverable_total(self) -> float:
        """Sum of per-finding recoveries — an upper bound, the fixes are
        counterfactuals of the SAME baseline and do not compose."""
        return sum(f.recoverable_seconds for f in self.findings)

    def table(self, width: int = 72) -> str:
        """Ranked ASCII findings table (the CLI's primary rendering)."""
        head = (f"doctor: {self.label or self.kind} — baseline "
                f"{self.baseline_seconds * 1e3:.3f} ms, "
                f"{len(self.findings)} finding"
                f"{'' if len(self.findings) == 1 else 's'}")
        if not self.findings:
            return head + "\n  (clean: no pathology above threshold)"
        lines = [head,
                 f"  {'#':>2s} {'recoverable':>12s} {'share':>6s} "
                 f"{'method':>11s}  pathology"]
        for i, f in enumerate(self.findings, 1):
            share = f.recoverable_seconds / self.baseline_seconds \
                if self.baseline_seconds > 0 else 0.0
            lines.append(f"  {i:>2d} {f.recoverable_seconds * 1e3:9.3f} ms "
                         f"{share * 100:5.1f}% {f.method:>11s}  {f.slug}")
            if f.affected:
                lines.append(f"     {'':>34s}{'':>1s}affected: "
                             + ", ".join(f.affected[:4]))
        return "\n".join(lines)

    def to_doc(self) -> Dict[str, Any]:
        return {"kind": self.kind, "label": self.label,
                "baseline_seconds": self.baseline_seconds,
                "recoverable_total_seconds": self.recoverable_total,
                "findings": [f.to_doc() for f in self.findings]}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_doc(), indent=indent)

    def to_chrome_events(self, pid: int = 0) -> List[dict]:
        """Annotation overlay: one ``doctor`` track with a span per finding
        (its time-lapse concentration window when known, else the whole
        run), composing with the PR 8 op/lapse/span tracks."""
        if not self.findings:
            return []
        events = [thread_meta("doctor", tid=_DOCTOR_TID, pid=pid)]
        for f in self.findings:
            args = {"recoverable_ms": round(f.recoverable_seconds * 1e3, 6),
                    "method": f.method,
                    **{k: round(v, 6) for k, v in f.evidence.items()}}
            if f.span_seconds is not None:
                t0, t1 = f.span_seconds
                events.append(duration_event(
                    f.slug, "doctor", t0, max(t1 - t0, 0.0),
                    tid=_DOCTOR_TID, pid=pid, args=args))
            else:
                events.append(duration_event(
                    f.slug, "doctor", 0.0, self.baseline_seconds,
                    tid=_DOCTOR_TID, pid=pid, args=args))
            if f.affected:
                events.append(instant_event(
                    f"{f.slug}: {f.affected[0]}", "doctor",
                    f.span_seconds[0] if f.span_seconds else 0.0,
                    tid=_DOCTOR_TID, pid=pid))
        return events


def _rank(findings: List[Finding], baseline: float,
          thresholds: Thresholds) -> List[Finding]:
    """Drop priced findings under the noise floor, rank by recovery.

    Recoveries are clamped to the baseline: analytic cluster estimates
    are in fleet-seconds and can exceed the wall-clock makespan, but no
    fix can recover more than the whole run."""
    floor = thresholds.min_recoverable_fraction * baseline
    kept = [f for f in findings
            if f.method == "unpriced" or f.recoverable_seconds >= floor]
    for f in kept:
        f.recoverable_seconds = min(f.recoverable_seconds, baseline)
    return sorted(kept, key=lambda f: -f.recoverable_seconds)


def diagnose_engine(report, engine=None, module=None, lapse=None,
                    thresholds: Thresholds = DEFAULT_THRESHOLDS,
                    label: str = "") -> DoctorReport:
    """Diagnose one engine run; price findings when the module is at hand.

    ``engine`` + ``module`` enable the counterfactual pass (tape replay,
    falling back to knob-override re-simulation); without them findings
    stay ``method="unpriced"`` and rank by detector order.
    """
    from repro.obs.trace import TRACER
    with TRACER.span("doctor.diagnose", kind="engine"):
        s = report.summary()
        findings = run_engine_detectors(report, s, lapse, thresholds)
        for f in findings:
            if f.slug not in ENGINE_WHATIFS:
                continue
            wi = whatif_engine(f.slug, report, engine=engine, module=module)
            if wi is None:
                continue
            f.recoverable_seconds = wi.recoverable_seconds
            f.method = wi.method
            f.evidence["ideal_seconds"] = wi.ideal_seconds
            if wi.detail and not f.detail:
                f.detail = wi.detail
    return DoctorReport("engine", label, report.total_seconds,
                        _rank(findings, report.total_seconds, thresholds))


def diagnose_cluster(report, lapse=None,
                     thresholds: Thresholds = DEFAULT_THRESHOLDS,
                     context: Optional[Dict[str, Any]] = None,
                     label: str = "") -> DoctorReport:
    """Diagnose one fleet run.  ``context`` may carry ``checkpoint`` (the
    run's :class:`~repro.faults.CheckpointModel`) and ``mtbf_s`` so the
    Young-Daly rule can price the cadence; cluster findings are analytic
    (no tape exists across the event loop)."""
    from repro.obs.trace import TRACER
    with TRACER.span("doctor.diagnose", kind="cluster"):
        s = report.summary()
        findings = run_cluster_detectors(report, s, lapse, thresholds,
                                         context)
    label = label or f"{report.trace_name} x {report.policy}"
    return DoctorReport("cluster", label, report.makespan_s,
                        _rank(findings, report.makespan_s, thresholds))


# ----------------------------------------------------------------------
# built-in demo workloads (CLI + CI smoke): hand-built HLO pathologies
# ----------------------------------------------------------------------
_DEMO_ELEMS = 1 << 20      # 4 MiB f32 buffers


def demo_module_src(pathology: str, n_ops: int = 8) -> str:
    """Hand-built HLO text exhibiting exactly one pathology.

    * ``"camping"`` — a serial chain of gathers into one shared table:
      every op camps the same placement-derived channel subset (the paper's
      §V pathology, worst case: full 1/CAMPING_FRACTION dilation);
    * ``"clean"`` — the contiguous twin: a negate chain with the identical
      per-op byte/flop profile (8 MiB moved, same vpu work) striped evenly;
    * ``"no-overlap"`` — compute serialized against all-reduces so the
      collectives sit fully exposed on the critical path.
    """
    n = _DEMO_ELEMS
    head = [f"ENTRY %main (p0: f32[{n}], idx: s32[{n}]) -> f32[{n}] {{",
            f"  %p0 = f32[{n}]{{0}} parameter(0)",
            f"  %idx = s32[{n}]{{0}} parameter(1)"]
    lines, prev = list(head), "idx"
    if pathology == "camping":
        for i in range(n_ops):
            root = "ROOT " if i == n_ops - 1 else ""
            lines.append(f"  {root}%g{i} = f32[{n}]{{0}} "
                         f"gather(%p0, %{prev}), offset_dims={{}}")
            prev = f"g{i}"
    elif pathology == "clean":
        lines.append(f"  %g0 = f32[{n}]{{0}} add(%p0, %p0)")
        prev = "g0"
        for i in range(1, n_ops):
            root = "ROOT " if i == n_ops - 1 else ""
            lines.append(f"  {root}%n{i} = f32[{n}]{{0}} negate(%{prev})")
            prev = f"n{i}"
    elif pathology == "no-overlap":
        for i in range(n_ops):
            root = "ROOT " if i == n_ops - 1 else ""
            lines.append(f"  %c{i} = f32[{n}]{{0}} negate(%{prev})")
            lines.append(f"  {root}%r{i} = f32[{n}]{{0}} "
                         f"all-reduce(%c{i}), replica_groups={{{{0,1,2,3}}}}")
            prev = f"r{i}"
    else:
        raise KeyError(f"unknown demo pathology {pathology!r} "
                       "(expected camping | clean | no-overlap)")
    lines.append("}")
    return "\n".join(lines)


def diagnose_demo(pathology: str, hw=None, n_ops: int = 8,
                  thresholds: Thresholds = DEFAULT_THRESHOLDS,
                  overlap: bool = True):
    """Simulate one built-in demo workload and diagnose it.

    Returns ``(DoctorReport, SimReport)`` — the CI smoke and ``python -m
    repro.obs doctor`` default path (no jax capture needed)."""
    from repro.core import V5E, Engine, parse_hlo_module
    from repro.obs.timelapse import TimeLapse
    hw = hw or V5E
    mod = parse_hlo_module(demo_module_src(pathology, n_ops))
    engine = Engine(hw=hw, overlap_collectives=overlap)
    report = engine.simulate(mod)
    lapse = TimeLapse.from_report(report, num_intervals=32,
                                  label=f"demo:{pathology}")
    doc = diagnose_engine(report, engine=engine, module=mod, lapse=lapse,
                          thresholds=thresholds,
                          label=f"demo:{pathology}")
    return doc, report
