"""Labeled counter/gauge/histogram registry — one interface for the
simulator's scattered operational counters.

Before this module, every layer grew its own tally: ``SimulationCache``
kept ``hits``/``misses`` attributes, the cluster loop kept HoL and
failure/reshape locals, and both CLIs re-implemented ``--self-profile``
stage timers.  They all still *compute* their numbers locally (hot loops
stay allocation-free), but they now publish into one process-wide
:class:`MetricsRegistry`, so "what happened in this process" is a single
queryable snapshot — the same reason production systems standardize on a
Prometheus-style registry instead of per-module globals.

Model (deliberately tiny, prometheus-shaped):

* :class:`Counter`   — monotone float, ``inc(v)``;
* :class:`Gauge`     — last-write-wins float, ``set(v)``;
* :class:`Histogram` — fixed-bucket counts + sum/count/min/max,
  ``observe(v)`` — enough for stage-latency distributions without
  keeping every sample.

Families are keyed by metric name; children by their sorted label tuple::

    from repro.obs.metrics import REGISTRY
    REGISTRY.counter("sim_cache_hits_total").inc()
    REGISTRY.counter("cluster_hol_events_total", policy="fifo").inc(3)
    REGISTRY.histogram("stage_seconds", cli="cluster",
                       stage="events").observe(1.25)
    REGISTRY.snapshot()   # {"cluster_hol_events_total{policy=fifo}": 3.0, ...}

:class:`StageTimer` is the shared ``--self-profile`` implementation both
CLIs use (one code path instead of two copy-pasted ``mark()`` closures):
it records per-stage wall seconds as registry histograms AND returns the
plain ``{stage: seconds}`` dict the JSON exports embed.
"""
from __future__ import annotations

import json
import time
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_key(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone counter child (one label set of one family)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counters only go up, got inc({v})")
        self.value += v


class Gauge:
    """Last-write-wins value child."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def dec(self, v: float = 1.0) -> None:
        self.value -= v


#: default histogram bucket upper bounds (seconds-flavored, log-spaced)
DEFAULT_BUCKETS = (0.001, 0.01, 0.1, 1.0, 10.0, 100.0)


class Histogram:
    """Fixed-bucket histogram child: counts per le-bucket + aggregates."""

    __slots__ = ("bounds", "bucket_counts", "count", "sum", "min", "max")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.bounds = tuple(sorted(bounds))
        self.bucket_counts = [0] * (len(self.bounds) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.bucket_counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                "buckets": {("+inf" if i == len(self.bounds)
                             else repr(self.bounds[i])): c
                            for i, c in enumerate(self.bucket_counts) if c}}


class MetricsRegistry:
    """Name+labels -> child instrument store with one snapshot interface."""

    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self):
        #: name -> (kind, {label key -> child})
        self._families: Dict[str, Tuple[str, Dict[LabelKey, Any]]] = {}

    def _child(self, kind: str, name: str, labels: Dict[str, Any],
               **ctor: Any):
        fam = self._families.get(name)
        if fam is None:
            fam = (kind, {})
            self._families[name] = fam
        elif fam[0] != kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{fam[0]}, requested {kind}")
        key = _label_key(labels)
        child = fam[1].get(key)
        if child is None:
            child = self._KINDS[kind](**ctor)
            fam[1][key] = child
        return child

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._child("counter", name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._child("gauge", name, labels)

    def histogram(self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels: Any) -> Histogram:
        return self._child("histogram", name, labels, bounds=buckets)

    # -- reading --------------------------------------------------------
    def get(self, name: str, **labels: Any) -> Optional[Any]:
        """The child for this exact (name, labels), or None (never creates)."""
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam[1].get(_label_key(labels))

    def value(self, name: str, **labels: Any) -> float:
        """Counter/gauge value (0.0 when absent); histograms return sum."""
        child = self.get(name, **labels)
        if child is None:
            return 0.0
        return child.sum if isinstance(child, Histogram) else child.value

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{rendered name: value-or-histogram-dict}`` of everything."""
        out: Dict[str, Any] = {}
        for name, (kind, children) in sorted(self._families.items()):
            for key, child in sorted(children.items()):
                rk = _render_key(name, key)
                out[rk] = (child.to_dict() if kind == "histogram"
                           else child.value)
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def clear(self) -> None:
        self._families.clear()

    def __len__(self) -> int:
        return sum(len(children) for _k, children in self._families.values())


#: the process-wide registry every instrumented layer publishes into
REGISTRY = MetricsRegistry()


class StageTimer:
    """The one ``--self-profile`` implementation (satellite of ISSUE 8).

    Both CLIs previously carried a private ``mark()`` closure over a
    ``prof`` dict; this is that closure, once, publishing each stage's
    wall seconds as a ``stage_seconds`` histogram labeled by CLI so
    repeated runs in one process accumulate a distribution::

        timer = StageTimer("cluster")
        ...capture work...
        timer.mark("capture")
        ...
        timer.stage_seconds     # {"capture": 1.25, ...} for JSON exports
        timer.render()          # the --self-profile stderr table

    Timing is always on (a ``perf_counter`` per stage boundary is free);
    ``--self-profile`` only controls whether the table is *printed*, so
    the JSON exports can carry ``stage_seconds`` unconditionally.
    """

    def __init__(self, cli: str, registry: Optional[MetricsRegistry] = None):
        self.cli = cli
        self.registry = REGISTRY if registry is None else registry
        self.stage_seconds: Dict[str, float] = {}
        self._last = time.perf_counter()

    def mark(self, stage: str) -> float:
        """Close the stage that just ran; returns its wall seconds."""
        now = time.perf_counter()
        dt = now - self._last
        self._last = now
        self.stage_seconds[stage] = self.stage_seconds.get(stage, 0.0) + dt
        self.registry.histogram("stage_seconds", cli=self.cli,
                                stage=stage).observe(dt)
        return dt

    @property
    def total_seconds(self) -> float:
        return sum(self.stage_seconds.values())

    def render(self) -> str:
        """The ``--self-profile`` table (one line per stage + total)."""
        total = self.total_seconds
        lines = ["self-profile (wall-clock):"]
        for stage, sec in self.stage_seconds.items():
            share = sec / total * 100 if total > 0 else 0.0
            lines.append(f"  {stage:<8s} {sec:8.3f} s  {share:5.1f}%")
        lines.append(f"  {'total':<8s} {total:8.3f} s")
        return "\n".join(lines)
