"""Hierarchical span tracer: a flight recorder for the simulator itself.

Every other trace in this repo is about *simulated* time (engine
timelines, fleet slices); this one is about the **simulator's own
wall-clock** — which stage of ``Engine.simulate`` a cluster run spends its
seconds in, how long one ``lower_collective`` miss takes, when the event
loop hit a FAIL/REPAIR burst.  That is the cross-layer question the
scattered ``--self-profile`` timers could not answer: a span records its
*ancestry*, so "this replay happened inside that gang start inside that
cluster run" survives into the export.

Design constraints (this code sits on the engine/cluster hot paths):

* **disabled by default, near-free when disabled** — :meth:`SpanTracer.
  span` returns a shared no-op context manager after a single attribute
  check, and :meth:`SpanTracer.instant` returns immediately; the
  perf gate in ``benchmarks/perf_core.py --trace-overhead`` holds the
  enabled-mode tax under 10% and the disabled mode inside the normal
  regression tolerance;
* **bounded memory** — records land in a ring buffer (default 65536
  spans): a million-job cluster run keeps the *most recent* window, the
  flight-recorder convention, and ``dropped`` counts what aged out;
* **hierarchical without bookkeeping at the call site** — the tracer
  maintains a depth/parent stack; ``with TRACER.span("engine.replay")``
  is the whole API.

Usage::

    from repro.obs.trace import TRACER
    TRACER.enable()
    with TRACER.span("cluster.run", policy="sjf"):
        ...
    events = TRACER.to_chrome_events()      # compose into any trace file

The module-level :data:`TRACER` is the instance every instrumented layer
(engine, fastsched, cluster events, topology lowering, faults) uses; tests
may build private :class:`SpanTracer` instances.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional, Tuple

#: chrome-trace pid reserved for simulator-self spans (simulated-time
#: tracks use pid 0), so both compose into one trace file without clashes
SELF_PID = 1


class SpanRecord:
    """One finished span (or zero-duration instant) in the flight recorder."""

    __slots__ = ("name", "t0", "t1", "depth", "parent", "seq", "attrs")

    def __init__(self, name: str, t0: float, t1: float, depth: int,
                 parent: Optional[str], seq: int,
                 attrs: Optional[Dict[str, Any]]):
        self.name = name
        self.t0 = t0              # perf_counter seconds, tracer-relative
        self.t1 = t1
        self.depth = depth
        self.parent = parent      # enclosing span's name, or None
        self.seq = seq            # monotone id (ring-buffer drop detection)
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "depth": self.depth, "parent": self.parent,
                "seq": self.seq, "attrs": self.attrs or {}}


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span: measures on ``__exit__`` and records itself."""

    __slots__ = ("_tracer", "name", "attrs", "t0")

    def __init__(self, tracer: "SpanTracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        tr = self._tracer
        tr._stack.append(self.name)
        self.t0 = time.perf_counter() - tr._epoch
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = time.perf_counter() - tr._epoch
        stack = tr._stack
        stack.pop()
        tr._record(SpanRecord(
            self.name, self.t0, t1, len(stack),
            stack[-1] if stack else None, next(tr._seq), self.attrs))
        return False


class SpanTracer:
    """Ring-buffered hierarchical span recorder (see module docstring)."""

    def __init__(self, capacity: int = 65536):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.enabled = False
        self._epoch = time.perf_counter()
        self._ring: deque = deque(maxlen=capacity)
        self._stack: List[str] = []
        self._seq = itertools.count()
        self._recorded = 0

    # -- recording ------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Context manager timing one span; no-op while disabled.

        Keyword arguments become the span's ``attrs`` payload (carried
        into the chrome-trace ``args``)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs or None)

    def instant(self, name: str, **attrs: Any) -> None:
        """Record a zero-duration marker (FAIL/REPAIR events, gang kills)."""
        if not self.enabled:
            return
        t = time.perf_counter() - self._epoch
        stack = self._stack
        self._record(SpanRecord(name, t, t, len(stack),
                                stack[-1] if stack else None,
                                next(self._seq), attrs or None))

    def _record(self, rec: SpanRecord) -> None:
        self._ring.append(rec)
        self._recorded += 1

    # -- lifecycle ------------------------------------------------------
    def enable(self) -> "SpanTracer":
        self.enabled = True
        return self

    def disable(self) -> "SpanTracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        self._ring.clear()
        self._stack.clear()
        self._recorded = 0
        self._epoch = time.perf_counter()

    # -- reading --------------------------------------------------------
    @property
    def records(self) -> List[SpanRecord]:
        """Current ring contents, oldest first (completion order)."""
        return list(self._ring)

    @property
    def dropped(self) -> int:
        """Spans that aged out of the ring (flight-recorder overwrite)."""
        return max(self._recorded - len(self._ring), 0)

    def drain(self) -> List[SpanRecord]:
        """Return and clear the ring (the stack/epoch keep running)."""
        out = list(self._ring)
        self._ring.clear()
        return out

    def iter_named(self, prefix: str) -> Iterator[SpanRecord]:
        return (r for r in self._ring if r.name.startswith(prefix))

    def total_seconds(self, name: str) -> float:
        """Summed duration of every recorded span with this exact name."""
        return sum(r.duration_s for r in self._ring if r.name == name)

    # -- export ---------------------------------------------------------
    def to_chrome_events(self, pid: int = SELF_PID) -> List[dict]:
        """Spans as Trace Event Format events on one lane per depth.

        Uses the shared helpers in :mod:`repro.obs.export`, so the result
        composes with engine / fleet / time-lapse tracks into one file.
        """
        from repro.obs.export import (duration_event, instant_event,
                                      thread_meta)
        if not self._ring:
            return []
        depths = sorted({r.depth for r in self._ring})
        events = [thread_meta(f"spans/depth{d}", tid=d, pid=pid)
                  for d in depths]
        for r in self._ring:
            args = dict(r.attrs or {})
            if r.parent:
                args["parent"] = r.parent
            if r.t1 > r.t0:
                events.append(duration_event(
                    r.name, "span", r.t0, r.t1 - r.t0, tid=r.depth, pid=pid,
                    args=args))
            else:
                events.append(instant_event(r.name, "span", r.t0,
                                            tid=r.depth, pid=pid, args=args))
        return events

    def summary(self) -> Dict[str, Tuple[int, float]]:
        """``{span name: (count, total seconds)}`` over the ring."""
        out: Dict[str, Tuple[int, float]] = {}
        for r in self._ring:
            n, s = out.get(r.name, (0, 0.0))
            out[r.name] = (n + 1, s + r.duration_s)
        return out


#: the process-wide tracer every instrumented layer reports to
TRACER = SpanTracer()
