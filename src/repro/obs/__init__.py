"""repro.obs — cross-layer observability: spans, metrics, time-lapse, diff.

The paper's central methodological tool is AerialVision: per-interval
time-lapse plots that exposed cuDNN's "many varying phases" and
partition-bank camping where aggregate counters showed nothing (§IV-V).
This package is that methodology applied to the whole simulator stack:

* :mod:`repro.obs.trace`     — hierarchical span tracer (the simulator's
  own wall-clock flight recorder, instrumented through engine /
  fastsched / cluster / topology / faults);
* :mod:`repro.obs.metrics`   — labeled counter/gauge/histogram registry
  absorbing the previously scattered counters, plus the shared
  :class:`~repro.obs.metrics.StageTimer` both CLIs use;
* :mod:`repro.obs.export`    — the one Chrome Trace Event Format helper
  set (and the shared ASCII shade ramp), so engine, fleet, span, and
  time-lapse tracks compose into one trace file;
* :mod:`repro.obs.timelapse` — AerialVision-style fixed-interval series
  (unit occupancy, channel-camping index, link utilization, queue
  depth) derived from existing timelines, reconciling to report totals;
* :mod:`repro.obs.manifest` / :mod:`repro.obs.diff` — run manifests and
  the ``python -m repro.obs diff`` regression attributor;
* :mod:`repro.obs.thresholds` — the single source of truth for every
  "how hot is pathological" cutoff (camping, imbalance, exposure);
* :mod:`repro.obs.detectors` / :mod:`repro.obs.whatif` /
  :mod:`repro.obs.doctor` — pluggable pathology detectors, the
  counterfactual what-if pricer (tape replay with patched step prices),
  and the ranked-findings doctor built on both;
* :mod:`repro.obs.sentinel` — the CI regression gate
  (``python -m repro.obs sentinel``, exit 0/3/2) and the
  ``BENCH_doctor.json`` trajectory.

Import structure note: ``trace``/``metrics``/``export`` are
dependency-free and imported eagerly — the engine and cluster layers
import them at module load.  ``timelapse``/``manifest``/``diff`` reach
back *into* those layers (analysis/cluster), so they are exposed lazily
via module ``__getattr__`` to keep the import graph acyclic.
"""
from __future__ import annotations

from repro.obs.export import (SHADES, counter_event, duration_event,
                              instant_event, shade, thread_meta, trace_json)
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               MetricsRegistry, StageTimer)
from repro.obs.thresholds import DEFAULT_THRESHOLDS, Thresholds
from repro.obs.trace import SELF_PID, SpanRecord, SpanTracer, TRACER

#: lazily-resolved symbols -> defining submodule (these import analysis /
#: cluster, which import the engine, which imports repro.obs.trace — an
#: eager import here would be circular)
_LAZY = {
    "TimeLapse": "repro.obs.timelapse",
    "LapseInterval": "repro.obs.timelapse",
    "CAMPED_THRESHOLD": "repro.obs.timelapse",
    "RunManifest": "repro.obs.manifest",
    "engine_manifest": "repro.obs.manifest",
    "cluster_manifest": "repro.obs.manifest",
    "ManifestDiff": "repro.obs.diff",
    "MetricDelta": "repro.obs.diff",
    "diff_manifests": "repro.obs.diff",
    "metric_layer": "repro.obs.diff",
    "resample_lapse_doc": "repro.obs.diff",
    "Finding": "repro.obs.detectors",
    "run_engine_detectors": "repro.obs.detectors",
    "run_cluster_detectors": "repro.obs.detectors",
    "WhatIf": "repro.obs.whatif",
    "whatif_engine": "repro.obs.whatif",
    "whatif_all": "repro.obs.whatif",
    "DoctorReport": "repro.obs.doctor",
    "diagnose_engine": "repro.obs.doctor",
    "diagnose_cluster": "repro.obs.doctor",
    "diagnose_demo": "repro.obs.doctor",
    "SentinelReport": "repro.obs.sentinel",
    "MetricVerdict": "repro.obs.sentinel",
    "sentinel_compare": "repro.obs.sentinel",
    "trajectory_entry": "repro.obs.sentinel",
    "append_trajectory": "repro.obs.sentinel",
    "parse_tolerances": "repro.obs.sentinel",
}


def __getattr__(name: str):
    mod_name = _LAZY.get(name)
    if mod_name is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod_name), name)


def __dir__():
    return sorted(list(globals()) + list(_LAZY))


__all__ = [
    "TRACER", "SpanTracer", "SpanRecord", "SELF_PID",
    "REGISTRY", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "StageTimer",
    "SHADES", "shade", "thread_meta", "duration_event", "counter_event",
    "instant_event", "trace_json",
    "Thresholds", "DEFAULT_THRESHOLDS",
    "TimeLapse", "LapseInterval", "CAMPED_THRESHOLD",
    "RunManifest", "engine_manifest", "cluster_manifest",
    "ManifestDiff", "MetricDelta", "diff_manifests", "metric_layer",
    "resample_lapse_doc",
    "Finding", "run_engine_detectors", "run_cluster_detectors",
    "WhatIf", "whatif_engine", "whatif_all",
    "DoctorReport", "diagnose_engine", "diagnose_cluster", "diagnose_demo",
    "SentinelReport", "MetricVerdict", "sentinel_compare",
    "trajectory_entry", "append_trajectory", "parse_tolerances",
]
